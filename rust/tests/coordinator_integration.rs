//! Coordinator under load: many requests, multiple workers, metric
//! aggregation, mixed request sizes, continuous-batching fairness,
//! scheduling policies, mid-flight cancellation, and KV admission control.

use specbranch::backend::sim::{SimBackend, SimConfig};
use specbranch::backend::Backend;
use specbranch::config::{EngineConfig, EngineId, ModelPair, PairId, Task, TaskId};
use specbranch::coordinator::{
    projected_admission_bytes, Coordinator, ResponseStatus, SchedulePolicy, SchedulerConfig,
    SubmitOpts,
};

fn backends(n: usize) -> Vec<Box<dyn Backend + Send>> {
    (0..n)
        .map(|_| {
            let cfg = SimConfig::new(
                ModelPair::get(PairId::Deepseek13b33b),
                Task::get(TaskId::HumanEval),
            );
            Box::new(SimBackend::new(cfg)) as Box<dyn Backend + Send>
        })
        .collect()
}

#[test]
fn hundred_requests_four_workers() {
    let coord = Coordinator::start(
        backends(4),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 30, ..Default::default() },
    );
    let n = 100;
    for i in 0..n {
        coord.submit(vec![1 + (i % 50) as u32, 2, 3], 30, i);
    }
    let mut total_tokens = 0;
    for _ in 0..n {
        let r = coord.collect();
        assert_eq!(r.tokens.len(), 30);
        total_tokens += r.tokens.len();
    }
    assert_eq!(total_tokens, 30 * n as usize);
    let snap = coord.registry();
    assert_eq!(snap.completed, n);
    assert!(snap.mean_decode_ms > 0.0);
    coord.shutdown();
}

#[test]
fn mixed_lengths_complete_exactly() {
    // Per-request budgets, all different from the engine config's default:
    // every response must have *exactly* the requested length, and the
    // coordinator aggregate must equal the per-request stats sum.
    let coord = Coordinator::start(
        backends(2),
        EngineId::Sps,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
    );
    let sizes = [7usize, 40, 150, 5, 50, 120, 10, 80];
    for (i, &sz) in sizes.iter().enumerate() {
        coord.submit(vec![2, 3, 4], sz, i as u64);
    }
    let mut got = std::collections::HashMap::new();
    let mut stats_sum = 0u64;
    for _ in 0..sizes.len() {
        let r = coord.collect();
        assert_eq!(
            r.tokens.len() as u64,
            r.stats.generated_tokens,
            "request {}: response length vs stats", r.id
        );
        stats_sum += r.stats.generated_tokens;
        got.insert(r.id, r.tokens.len());
    }
    for (i, &sz) in sizes.iter().enumerate() {
        assert_eq!(got[&(i as u64)], sz, "request {i}");
    }
    let snap = coord.registry();
    assert_eq!(snap.generated_tokens, stats_sum);
    assert_eq!(snap.generated_tokens as usize, sizes.iter().sum::<usize>());
    coord.shutdown();
}

#[test]
fn fifo_fairness_single_worker() {
    // Round-robin round scheduling on one worker: equal-work requests
    // (AR: one round per token, deterministic) complete in submission
    // order.
    let coord = Coordinator::start(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 12, ..Default::default() },
    );
    let ids: Vec<u64> = (0..6).map(|i| coord.submit(vec![1, 2, 3], 12, i)).collect();
    let mut got = Vec::new();
    for _ in 0..ids.len() {
        got.push(coord.collect().id);
    }
    assert_eq!(got, ids, "equal work must complete FIFO on one worker");
    coord.shutdown();
}

#[test]
fn no_head_of_line_blocking_on_mixed_workload() {
    // The acceptance workload: 12 mixed-length requests on 2 sim workers.
    // The short requests are enqueued *after* all the long ones and must
    // still finish first — workers schedule rounds, not whole requests.
    let coord = Coordinator::start(
        backends(2),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 512, ..Default::default() },
    );
    let mut long_ids = Vec::new();
    for i in 0..9u64 {
        long_ids.push(coord.submit(vec![1, 2, 3], 250, i));
    }
    let mut short_ids = std::collections::HashSet::new();
    for i in 0..3u64 {
        short_ids.insert(coord.submit(vec![4, 5, 6], 6, 100 + i));
    }
    // The three short requests must be the first three completions.
    for _ in 0..3 {
        let r = coord.collect();
        assert!(
            short_ids.remove(&r.id),
            "a 250-token request finished before a 6-token one (id {})",
            r.id
        );
        assert_eq!(r.tokens.len(), 6);
    }
    for _ in 0..long_ids.len() {
        assert_eq!(coord.collect().tokens.len(), 250);
    }
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn shutdown_with_inflight_requests_drains_cleanly() {
    let coord = Coordinator::start(
        backends(2),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
    );
    let sizes = [20usize, 45, 8, 33];
    for (i, &sz) in sizes.iter().enumerate() {
        coord.submit(vec![1, 2, 3], sz, i as u64);
    }
    // Immediate shutdown: queued and in-flight requests all finish with
    // their exact budgets; undelivered responses come back.
    let mut rest = coord.shutdown();
    assert_eq!(rest.len(), sizes.len());
    rest.sort_by_key(|r| r.id);
    for (r, &sz) in rest.iter().zip(sizes.iter()) {
        assert_eq!(r.tokens.len(), sz);
        assert_eq!(r.stats.generated_tokens as usize, sz);
    }
}

#[test]
fn cancel_queued_request_before_admission() {
    // One worker, a window-filling backlog: the last submitted request is
    // still waiting in the admission queue and can be cancelled before any
    // decode work happens.
    let coord = Coordinator::start(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
    );
    let mut ids = Vec::new();
    for i in 0..40u64 {
        ids.push(coord.submit(vec![1, 2, 3], 60, i));
    }
    let victim = *ids.last().unwrap();
    assert!(coord.cancel(victim), "queued request must be cancellable");
    let r = coord.collect_id(victim);
    assert_eq!(r.status, ResponseStatus::Cancelled);
    assert!(r.tokens.is_empty(), "never admitted -> no tokens");
    assert_eq!(r.stats.generated_tokens, 0);
    let rest = coord.shutdown();
    assert_eq!(rest.len(), 39, "every other request still completes");
    for r in &rest {
        assert_eq!(r.tokens.len(), 60);
        assert_eq!(r.status, ResponseStatus::Completed);
    }
}

#[test]
fn cancel_mid_decode_returns_partial_tokens() {
    // Stream the first round, then cancel: the response must carry exactly
    // the partial tokens committed so far, with consistent stats, and the
    // stream must still terminate with done=true.
    let coord = Coordinator::start(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let id = coord.submit_streaming(vec![1, 2, 3], 8000, 7, tx);
    // Block until the first round has committed — the task is now mid-
    // decode with ~8000 rounds of budget left, so cancellation cannot race
    // completion.
    let first = rx.recv().expect("first round chunk");
    assert!(!first.done, "8000-token request cannot finish in one round");
    assert!(coord.cancel(id), "mid-decode request must be cancellable");
    let r = coord.collect_id(id);
    assert_eq!(r.status, ResponseStatus::Cancelled);
    assert!(!r.tokens.is_empty(), "partial output preserved");
    assert!(r.tokens.len() < 8000, "cancelled well before the budget");
    assert_eq!(
        r.tokens.len() as u64,
        r.stats.generated_tokens,
        "partial tokens and stats must agree"
    );
    // Drain the stream: chunks concatenate to the partial response and the
    // cancellation flushed a terminating done=true.
    let mut streamed = first.tokens.clone();
    let mut saw_done = false;
    while let Ok(chunk) = rx.try_recv() {
        streamed.extend(chunk.tokens);
        if chunk.done {
            saw_done = true;
        }
    }
    assert!(saw_done, "cancelled stream must terminate");
    assert_eq!(streamed, r.tokens);
    let snap = coord.registry();
    assert_eq!(snap.cancelled, 1);
    assert_eq!(
        snap.generated_tokens, r.stats.generated_tokens,
        "registry counts the cancelled request's partial tokens"
    );
    assert_eq!(coord.kv_projected_in_use(), 0, "KV projection released");
    coord.shutdown();
}

#[test]
fn mixed_cancel_complete_workload_keeps_registry_invariant() {
    // The acceptance workload: cancellations interleaved with completions;
    // the registry token count must equal the sum of per-response stats,
    // partial tokens included, and the KV projection must drain to zero.
    let coord = Coordinator::start(
        backends(2),
        EngineId::Sps,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
    );
    let ids: Vec<u64> = (0..8).map(|i| coord.submit(vec![1, 2, 3], 2000, i)).collect();
    assert!(coord.cancel(ids[2]));
    assert!(coord.cancel(ids[5]));
    let mut stats_sum = 0u64;
    let mut cancelled = 0;
    let mut completed = 0;
    for _ in 0..ids.len() {
        let r = coord.collect();
        assert_eq!(r.tokens.len() as u64, r.stats.generated_tokens);
        stats_sum += r.stats.generated_tokens;
        match r.status {
            ResponseStatus::Cancelled => {
                cancelled += 1;
                assert!(r.tokens.len() < 2000);
                assert!(r.id == ids[2] || r.id == ids[5]);
            }
            ResponseStatus::Completed => {
                completed += 1;
                assert_eq!(r.tokens.len(), 2000);
            }
        }
    }
    assert_eq!(cancelled, 2);
    assert_eq!(completed, 6);
    let snap = coord.registry();
    assert_eq!(snap.cancelled, 2);
    assert_eq!(snap.completed, 6);
    assert_eq!(
        snap.generated_tokens, stats_sum,
        "registry == sum of per-request stats under mixed cancel/complete"
    );
    assert_eq!(coord.kv_projected_in_use(), 0);
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn edf_prefers_tight_deadline_that_round_robin_makes_wait() {
    // Two equal-length requests on one worker. Under round-robin their
    // rounds interleave, so the first-submitted request finishes first and
    // the tight-deadline latecomer pays ~2x its own decode time — the miss.
    // Under EDF the tight-deadline request runs every round until done and
    // finishes first, meeting its deadline.
    let submit_pair = |coord: &Coordinator| -> (u64, u64) {
        let a = coord.submit_opts(vec![1, 2, 3], 200, 1, SubmitOpts::default());
        let b = coord.submit_opts(vec![4, 5, 6], 200, 2, SubmitOpts::new().deadline_ms(30_000));
        (a, b)
    };

    let edf = Coordinator::start_with(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 256, ..Default::default() },
        SchedulerConfig::default().with_policy(SchedulePolicy::EarliestDeadline),
    );
    let (_a, b) = submit_pair(&edf);
    let first = edf.collect();
    assert_eq!(first.id, b, "EDF runs the deadlined request to completion first");
    assert_eq!(first.deadline_met, Some(true), "tight deadline met under EDF");
    let second = edf.collect();
    assert_eq!(second.deadline_met, None, "no deadline -> no verdict");
    edf.shutdown();

    let rr = Coordinator::start(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 256, ..Default::default() },
    );
    let (a, _b) = submit_pair(&rr);
    let first = rr.collect();
    assert_eq!(
        first.id, a,
        "round-robin interleaves, so the deadlined latecomer waits"
    );
    rr.shutdown();
}

#[test]
fn priority_aging_bounds_low_priority_wait() {
    // Six long high-priority requests and one short low-priority request on
    // one worker. With aging, the low-priority request's effective priority
    // rises while it waits, so it starts receiving rounds once its deficit
    // reaches aging_rounds x (priority gap) and finishes long before the
    // high-priority work drains — bounded wait, no starvation. With aging
    // disabled (pure priority) it is served dead last.
    let cfg = EngineConfig { max_new_tokens: 256, ..Default::default() };
    let run = |aging_rounds: u64| -> (u64, Vec<u64>) {
        let coord = Coordinator::start_with(
            backends(1),
            EngineId::Autoregressive,
            cfg.clone(),
            SchedulerConfig::default()
                .with_policy(SchedulePolicy::Priority)
                .with_aging_rounds(aging_rounds),
        );
        for i in 0..6u64 {
            coord.submit_opts(vec![1, 2, 3], 80, i, SubmitOpts::new().priority(5));
        }
        let low = coord.submit_opts(vec![4, 5, 6], 8, 99, SubmitOpts::default());
        let mut order = Vec::new();
        for _ in 0..7 {
            order.push(coord.collect().id);
        }
        coord.shutdown();
        (low, order)
    };

    let (low, order) = run(4);
    assert_eq!(
        order.first().copied(),
        Some(low),
        "aged low-priority short request finishes before the long high-priority pile"
    );
    let (low, order) = run(0);
    assert_eq!(
        order.last().copied(),
        Some(low),
        "without aging, pure priority serves the low-priority request last"
    );
}

#[test]
fn admission_watermark_bounds_kv_with_zero_drops() {
    // Oversubscription stress: 12 requests whose combined KV projection is
    // ~6x the watermark. Admission control must keep the projected peak
    // under the watermark while every request still completes in full.
    let watermark = 2_000_000usize;
    let coord = Coordinator::start_with(
        backends(2),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 64, gamma: 6, k_max: 4, ..Default::default() },
        SchedulerConfig::default().with_kv_watermark_bytes(Some(watermark)),
    );
    let n = 12u64;
    for i in 0..n {
        coord.submit(vec![1, 2, 3], 40, i);
    }
    for _ in 0..n {
        let r = coord.collect();
        assert_eq!(r.status, ResponseStatus::Completed, "zero dropped requests");
        assert_eq!(r.tokens.len(), 40);
    }
    let snap = coord.registry();
    assert_eq!(snap.completed, n);
    assert_eq!(snap.cancelled, 0);
    assert!(
        snap.kv_projected_peak_bytes as usize <= watermark,
        "peak projected KV {} exceeded watermark {}",
        snap.kv_projected_peak_bytes,
        watermark
    );
    assert!(snap.kv_projected_peak_bytes > 0, "admissions were accounted");
    assert!(
        snap.admission_deferrals > 0,
        "a 6x-oversubscribed workload must defer admissions"
    );
    assert_eq!(coord.kv_projected_in_use(), 0, "projection drains with the pool");
    coord.shutdown();
}

#[test]
fn shutdown_drains_requests_deferred_by_admission_control() {
    // Requests still waiting in the admission queue — including ones the KV
    // watermark is deferring — must not be lost by shutdown.
    let coord = Coordinator::start_with(
        backends(1),
        EngineId::Sps,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
        // Roughly one admitted request at a time.
        SchedulerConfig::default().with_kv_watermark_bytes(Some(1_000_000)),
    );
    for i in 0..6 {
        coord.submit(vec![1, 2, 3], 30, i);
    }
    let mut rest = coord.shutdown();
    assert_eq!(rest.len(), 6, "deferred admissions drain on shutdown");
    rest.sort_by_key(|r| r.id);
    for r in &rest {
        assert_eq!(r.status, ResponseStatus::Completed);
        assert_eq!(r.tokens.len(), 30);
    }
}

#[test]
fn fused_verification_keeps_registry_invariant_under_mixed_cancellation() {
    // The PR 1/2 acceptance invariant under the fused-verification
    // scheduler: cancellations interleaved with completions, rounds running
    // as cross-request batched target passes — the registry token count
    // must still equal the sum of per-response stats (partial tokens
    // included) and the KV projection must drain to zero.
    let coord = Coordinator::start_with(
        backends(2),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
        SchedulerConfig::default().with_verify_batch(4),
    );
    let ids: Vec<u64> = (0..8).map(|i| coord.submit(vec![1, 2, 3], 1500, i)).collect();
    assert!(coord.cancel(ids[2]));
    assert!(coord.cancel(ids[5]));
    let mut stats_sum = 0u64;
    let mut cancelled = 0;
    let mut completed = 0;
    for _ in 0..ids.len() {
        let r = coord.collect();
        assert_eq!(r.tokens.len() as u64, r.stats.generated_tokens);
        stats_sum += r.stats.generated_tokens;
        match r.status {
            ResponseStatus::Cancelled => {
                cancelled += 1;
                assert!(r.tokens.len() < 1500);
                assert!(r.id == ids[2] || r.id == ids[5]);
            }
            ResponseStatus::Completed => {
                completed += 1;
                assert_eq!(r.tokens.len(), 1500);
            }
        }
    }
    assert_eq!(cancelled, 2);
    assert_eq!(completed, 6);
    let snap = coord.registry();
    assert_eq!(snap.cancelled, 2);
    assert_eq!(snap.completed, 6);
    assert_eq!(
        snap.generated_tokens, stats_sum,
        "registry == sum of per-request stats under fused passes + cancellation"
    );
    assert!(snap.batched_rounds > 0, "the workload must actually fuse");
    assert!(snap.mean_fused_width > 1.0);
    assert_eq!(coord.kv_projected_in_use(), 0);
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn fused_streams_match_unbatched_across_workers() {
    // Greedy losslessness through the serving path: the per-request token
    // streams of a fused-verification coordinator must be byte-identical
    // to the unbatched coordinator's (fusing re-prices the clock only).
    let run = |verify_batch: usize| -> Vec<(u64, Vec<u32>)> {
        let coord = Coordinator::start_with(
            backends(2),
            EngineId::SpecBranch,
            EngineConfig { max_new_tokens: 40, ..Default::default() },
            SchedulerConfig::default().with_verify_batch(verify_batch),
        );
        for i in 0..10u64 {
            coord.submit(vec![1, 2, 3, 1 + (i as u32 % 5)], 40, i);
        }
        let mut out: Vec<(u64, Vec<u32>)> = (0..10)
            .map(|_| {
                let r = coord.collect();
                (r.id, r.tokens)
            })
            .collect();
        out.sort_by_key(|(id, _)| *id);
        coord.shutdown();
        out
    };
    assert_eq!(run(1), run(4), "fused and unbatched streams must match");
}

#[test]
fn edf_orders_the_batch_composition() {
    // verify_batch=2, one worker, three deadlined requests with a strict
    // EDF order B < A < C. Every width-2 batch while B lives must be
    // composed as {B, A} — C is excluded from the batch until B retires —
    // so B (short) completes first, and A (which rode every cycle) beats C
    // (which only started once B freed its lane). Completion order: B, A, C.
    let coord = Coordinator::start_with(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 512, ..Default::default() },
        SchedulerConfig::default()
            .with_policy(SchedulePolicy::EarliestDeadline)
            .with_verify_batch(2),
    );
    let deadline = |ms: u64| SubmitOpts::new().deadline_ms(ms);
    let a = coord.submit_opts(vec![1, 2, 3], 400, 1, deadline(60_000));
    let b = coord.submit_opts(vec![4, 5, 6], 150, 2, deadline(30_000));
    let c = coord.submit_opts(vec![7, 8, 9], 400, 3, deadline(90_000));
    let order: Vec<u64> = (0..3).map(|_| coord.collect().id).collect();
    assert_eq!(
        order,
        vec![b, a, c],
        "EDF must order the fused batch composition by deadline"
    );
    coord.shutdown();
}

#[test]
fn priority_orders_the_batch_composition() {
    // Same shape under the priority policy (aging off) with a strict
    // priority order B > A > C: B rides every width-2 batch until done, A
    // holds the second lane, C waits for a free lane.
    let coord = Coordinator::start_with(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 512, ..Default::default() },
        SchedulerConfig::default()
            .with_policy(SchedulePolicy::Priority)
            .with_aging_rounds(0)
            .with_verify_batch(2),
    );
    let pri = |p: i32| SubmitOpts::new().priority(p);
    let a = coord.submit_opts(vec![1, 2, 3], 400, 1, pri(3));
    let b = coord.submit_opts(vec![4, 5, 6], 150, 2, pri(5));
    let c = coord.submit_opts(vec![7, 8, 9], 400, 3, pri(1));
    let order: Vec<u64> = (0..3).map(|_| coord.collect().id).collect();
    assert_eq!(
        order,
        vec![b, a, c],
        "priority must order the fused batch composition"
    );
    coord.shutdown();
}

#[test]
fn preemption_reclaims_kv_then_resumes_byte_identical_exact_budgets() {
    // Tentpole acceptance: under a watermark too small for the workload,
    // the low-priority inflight request is preempted (KV reclaimed), the
    // high-priority 7/40/150 mix runs, and the victim later resumes and
    // completes with a token stream byte-identical to the unconstrained
    // run — exact budgets, one registry count per request across the
    // preempt/resume cycle.
    let e_cfg = EngineConfig { max_new_tokens: 1024, ..Default::default() };
    let base = SchedulerConfig::default().with_policy(SchedulePolicy::Priority);
    let proj_600 = projected_admission_bytes(3, 600, &e_cfg, &base);
    let proj_7 = projected_admission_bytes(3, 7, &e_cfg, &base);
    // Fits the 600-budget victim alone, not together with even the
    // 7-budget arrival: the high-priority burst must preempt to get in.
    let tight = base
        .clone()
        .with_kv_watermark_bytes(Some(proj_600 + proj_7 / 2))
        .with_preempt(true);
    let mix = [7usize, 40, 150];

    // Unconstrained reference: same submission order => same ids => same
    // per-request seeds => same deterministic greedy streams.
    let reference = {
        let coord =
            Coordinator::start_with(backends(1), EngineId::SpecBranch, e_cfg.clone(), base);
        coord.submit_opts(vec![1, 2, 3], 600, 5, SubmitOpts::default());
        for (i, &sz) in mix.iter().enumerate() {
            coord.submit_opts(
                vec![4 + i as u32, 5, 6],
                sz,
                6 + i as u64,
                SubmitOpts::new().priority(9),
            );
        }
        let mut out = std::collections::HashMap::new();
        for _ in 0..4 {
            let r = coord.collect();
            out.insert(r.id, r.tokens);
        }
        coord.shutdown();
        out
    };

    let coord = Coordinator::start_with(backends(1), EngineId::SpecBranch, e_cfg, tight);
    let (tx, rx) = std::sync::mpsc::channel();
    let victim = coord.submit_opts(vec![1, 2, 3], 600, 5, SubmitOpts::new().stream(tx));
    // Wait for the victim's first committed round, so the high-priority
    // arrivals land mid-flight and must preempt rather than defer.
    let first = rx.recv().expect("victim first chunk");
    assert!(!first.done, "a 600-token request cannot finish in one round");
    let hi_ids: Vec<u64> = mix
        .iter()
        .enumerate()
        .map(|(i, &sz)| {
            coord.submit_opts(
                vec![4 + i as u32, 5, 6],
                sz,
                6 + i as u64,
                SubmitOpts::new().priority(9),
            )
        })
        .collect();
    let mut got = std::collections::HashMap::new();
    let mut stats_sum = 0u64;
    let mut order = Vec::new();
    for _ in 0..4 {
        let r = coord.collect();
        assert_eq!(r.status, ResponseStatus::Completed);
        assert_eq!(
            r.tokens.len() as u64,
            r.stats.generated_tokens,
            "request {}: counters must agree across preempt/resume",
            r.id
        );
        stats_sum += r.stats.generated_tokens;
        order.push(r.id);
        got.insert(r.id, r.tokens);
    }
    assert_eq!(got[&victim].len(), 600, "preempted victim still gets its exact budget");
    for (i, &sz) in mix.iter().enumerate() {
        assert_eq!(got[&hi_ids[i]].len(), sz, "exact budget for the {sz}-token request");
    }
    assert_eq!(
        order.last().copied(),
        Some(victim),
        "the victim resumes only after the high-priority work frees the watermark"
    );
    for (id, tokens) in &reference {
        assert_eq!(
            &got[id], tokens,
            "request {id}: stream must be byte-identical to the unconstrained run"
        );
    }
    let snap = coord.registry();
    assert!(snap.preemptions >= 1, "the tight watermark must preempt");
    assert_eq!(snap.resumed, snap.preemptions, "every preemption is resumed");
    assert!(snap.repeat_prefill_tokens > 0, "resume re-prefilled prompt + committed");
    assert!(snap.kv_reclaimed_bytes > 0, "preemption reclaimed measured KV bytes");
    assert_eq!(
        snap.generated_tokens, stats_sum,
        "registry counts each request once across preempt/resume"
    );
    assert_eq!(snap.generated_tokens as usize, 600 + 7 + 40 + 150);
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.cancelled, 0);
    assert_eq!(coord.kv_projected_in_use(), 0, "projection drains to zero");
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn oversized_arrival_preempts_inflight_and_is_admitted_alone() {
    // The oversized-admitted-alone rule interacting with preemption: an
    // arrival whose projection alone exceeds the watermark outranks the
    // inflight victim, preempts it to drain the cache to zero, runs alone
    // (projection above the watermark), and the victim resumes after.
    let e_cfg = EngineConfig { max_new_tokens: 1024, ..Default::default() };
    let base = SchedulerConfig::default().with_policy(SchedulePolicy::Priority);
    let proj_300 = projected_admission_bytes(3, 300, &e_cfg, &base);
    let proj_700 = projected_admission_bytes(3, 700, &e_cfg, &base);
    let watermark = proj_300 + proj_300 / 2;
    assert!(proj_700 > watermark, "the big request must be oversized for the watermark");
    let coord = Coordinator::start_with(
        backends(1),
        EngineId::Sps,
        e_cfg,
        base.with_kv_watermark_bytes(Some(watermark)).with_preempt(true),
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let victim = coord.submit_opts(vec![1, 2, 3], 300, 0, SubmitOpts::new().stream(tx));
    assert!(!rx.recv().expect("victim round").done);
    let big = coord.submit_opts(vec![4, 5, 6], 700, 1, SubmitOpts::new().priority(9));
    let first = coord.collect();
    assert_eq!(first.id, big, "the oversized request runs alone while the victim waits");
    assert_eq!(first.tokens.len(), 700);
    assert_eq!(first.status, ResponseStatus::Completed);
    let second = coord.collect_id(victim);
    assert_eq!(second.tokens.len(), 300, "the victim still completes exactly");
    assert_eq!(second.status, ResponseStatus::Completed);
    let snap = coord.registry();
    assert_eq!(snap.preemptions, 1, "one preemption drains the cache for the oversized run");
    assert_eq!(snap.resumed, 1);
    assert!(
        snap.kv_projected_peak_bytes as usize >= proj_700,
        "the oversized projection was admitted alone above the watermark"
    );
    assert_eq!(coord.kv_projected_in_use(), 0);
    coord.shutdown();
}

#[test]
fn pathological_watermark_preempt_resume_makes_progress_no_livelock() {
    // Hysteresis acceptance: a 1-byte watermark makes every request
    // oversized (each admitted alone) and every higher-priority arrival a
    // preemptor. The resume shield (at least one completed round before
    // the next preemption) guarantees forward progress, so the whole mixed
    // workload still completes with exact budgets — no preempt/resume
    // livelock, registry equality intact.
    let e_cfg = EngineConfig { max_new_tokens: 256, ..Default::default() };
    let coord = Coordinator::start_with(
        backends(1),
        EngineId::SpecBranch,
        e_cfg,
        SchedulerConfig::default()
            .with_policy(SchedulePolicy::Priority)
            .with_kv_watermark_bytes(Some(1))
            .with_preempt(true)
            .with_aging_rounds(2),
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let first = coord.submit_opts(vec![1, 2, 3], 240, 0, SubmitOpts::new().stream(tx));
    assert!(!rx.recv().expect("first round").done);
    let mut ids = vec![first];
    for (i, &p) in [5i32, 3, 9, 1].iter().enumerate() {
        ids.push(coord.submit_opts(
            vec![2 + i as u32, 3, 4],
            240,
            1 + i as u64,
            SubmitOpts::new().priority(p),
        ));
    }
    let mut stats_sum = 0u64;
    for _ in 0..ids.len() {
        let r = coord.collect();
        assert_eq!(r.status, ResponseStatus::Completed);
        assert_eq!(r.tokens.len(), 240, "exact budget for request {}", r.id);
        assert_eq!(r.tokens.len() as u64, r.stats.generated_tokens);
        stats_sum += r.stats.generated_tokens;
    }
    let snap = coord.registry();
    assert!(snap.preemptions >= 1, "higher-priority arrivals must preempt");
    assert_eq!(snap.resumed, snap.preemptions);
    assert_eq!(snap.generated_tokens, stats_sum);
    assert_eq!(snap.generated_tokens, 5 * 240);
    assert_eq!(coord.kv_projected_in_use(), 0);
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn cancel_while_preempted_returns_partial_and_registry_holds() {
    // Mixed cancel + preempt + complete: a request preempted and waiting
    // for re-admission is cancelled — its response carries the
    // checkpoint's partial tokens with real stats and it never resumes;
    // a second cancellation lands mid-decode; two more requests complete.
    // The registry token equality must span all of it.
    let e_cfg = EngineConfig { max_new_tokens: 8192, ..Default::default() };
    let base = SchedulerConfig::default().with_policy(SchedulePolicy::Priority);
    let proj_400 = projected_admission_bytes(3, 400, &e_cfg, &base);
    let watermark = proj_400 + proj_400 / 2;
    let coord = Coordinator::start_with(
        backends(1),
        EngineId::SpecBranch,
        e_cfg,
        base.with_kv_watermark_bytes(Some(watermark)).with_preempt(true),
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let victim = coord.submit_opts(vec![1, 2, 3], 400, 0, SubmitOpts::new().stream(tx));
    assert!(!rx.recv().expect("victim round").done);
    // An oversized long-running high-priority request: preempts the victim
    // and then holds the cache, so the victim must sit in the admission
    // queue as a resumable entry (it cannot re-fit while the big one runs).
    let big = coord.submit_opts(vec![4, 5, 6], 8000, 1, SubmitOpts::new().priority(9));
    let mut polls = 0;
    while coord.registry().preemptions == 0 {
        polls += 1;
        assert!(polls < 10_000, "the oversized arrival never preempted the victim");
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(coord.cancel(victim), "preempted request must be cancellable while queued");
    let r_victim = coord.collect_id(victim);
    assert_eq!(r_victim.status, ResponseStatus::Cancelled);
    assert!(!r_victim.tokens.is_empty(), "partial tokens from before the preemption survive");
    assert!(r_victim.tokens.len() < 400);
    assert_eq!(r_victim.tokens.len() as u64, r_victim.stats.generated_tokens);
    let mut stats_sum = r_victim.stats.generated_tokens;
    // Cancel the big one mid-decode, then run two ordinary completions.
    assert!(coord.cancel(big));
    let r_big = coord.collect_id(big);
    assert_eq!(r_big.status, ResponseStatus::Cancelled);
    assert_eq!(r_big.tokens.len() as u64, r_big.stats.generated_tokens);
    stats_sum += r_big.stats.generated_tokens;
    let c1 = coord.submit_opts(vec![5, 6, 7], 80, 2, SubmitOpts::default());
    let c2 = coord.submit_opts(vec![6, 7, 8], 80, 3, SubmitOpts::default());
    for id in [c1, c2] {
        let r = coord.collect_id(id);
        assert_eq!(r.status, ResponseStatus::Completed);
        assert_eq!(r.tokens.len(), 80);
        stats_sum += r.stats.generated_tokens;
    }
    let snap = coord.registry();
    assert_eq!(snap.cancelled, 2);
    assert_eq!(snap.completed, 2);
    assert!(snap.preemptions >= 1);
    assert_eq!(snap.resumed, 0, "a victim cancelled while queued never resumes");
    assert!(snap.kv_reclaimed_bytes > 0);
    assert_eq!(
        snap.generated_tokens, stats_sum,
        "registry == sum of per-request stats across cancel + preempt + complete"
    );
    assert_eq!(coord.kv_projected_in_use(), 0);
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn preempt_resume_hits_prefix_cache_with_identical_streams() {
    // A preempted victim's resume re-prefill of prompt ⊕ committed must hit
    // the cross-request prefix cache (the checkpoint published the committed
    // chain when it released KV), so each resume charges at most the final
    // partial block — while the committed streams stay byte-identical to a
    // cache-off twin and the registry still equals the per-response sum.
    use specbranch::kvcache::{PrefixCache, BLOCK_TOKENS};
    use std::sync::Arc;

    let prompt: Vec<u32> = (1..=40).collect();
    let run = |cache: Option<Arc<PrefixCache>>| {
        let backends: Vec<Box<dyn Backend + Send>> = (0..1)
            .map(|_| {
                let mut cfg = SimConfig::new(
                    ModelPair::get(PairId::Deepseek13b33b),
                    Task::get(TaskId::HumanEval),
                );
                cfg.prefix = cache.clone();
                Box::new(SimBackend::new(cfg)) as Box<dyn Backend + Send>
            })
            .collect();
        let coord = Coordinator::start_with(
            backends,
            EngineId::SpecBranch,
            EngineConfig { max_new_tokens: 256, ..Default::default() },
            SchedulerConfig::default()
                .with_policy(SchedulePolicy::Priority)
                .with_kv_watermark_bytes(Some(1))
                .with_preempt(true)
                .with_prefix_cache(cache.clone()),
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let victim = coord.submit_opts(prompt.clone(), 200, 3, SubmitOpts::new().stream(tx));
        // First committed round: the rider provably lands mid-flight, and
        // the victim's shield has cleared, so the 1-byte watermark preempts.
        assert!(!rx.recv().expect("victim first round").done);
        let rider = coord.submit_opts(vec![90, 91, 92], 32, 4, SubmitOpts::new().priority(9));
        let mut out = std::collections::HashMap::new();
        let mut stats_sum = 0u64;
        let mut victim_stats = None;
        for _ in 0..2 {
            let r = coord.collect();
            assert_eq!(r.status, ResponseStatus::Completed);
            assert_eq!(r.tokens.len() as u64, r.stats.generated_tokens);
            stats_sum += r.stats.generated_tokens;
            if r.id == victim {
                assert_eq!(r.tokens.len(), 200);
                victim_stats = Some(r.stats.clone());
            } else {
                assert_eq!(r.id, rider);
                assert_eq!(r.tokens.len(), 32);
            }
            out.insert(r.id, r.tokens);
        }
        let snap = coord.registry();
        assert_eq!(snap.generated_tokens, stats_sum, "registry == Σ per-response stats");
        assert!(snap.preemptions >= 1, "the 1-byte watermark must preempt the victim");
        assert_eq!(snap.resumed, snap.preemptions);
        assert_eq!(coord.kv_projected_in_use(), 0);
        coord.shutdown();
        (out, victim_stats.unwrap(), snap)
    };

    let cache = Arc::new(PrefixCache::new(1 << 20));
    let (cached_streams, victim_on, snap_on) = run(Some(cache));
    let (plain_streams, victim_off, snap_off) = run(None);
    assert_eq!(cached_streams, plain_streams, "prefix cache must not change any stream");

    // Cache-off charges the full context on the first prefill *and* every
    // resume re-prefill; cache-on finds the published chain and re-charges
    // only the uncached tail (≤ one block per resume).
    assert_eq!(victim_off.prefill_cached_tokens, 0);
    assert_eq!(snap_off.prefix_hits, 0);
    assert!(
        victim_on.prefill_cached_tokens >= 2 * BLOCK_TOKENS as u64,
        "resume must reuse the published prompt ⊕ committed chain (cached {})",
        victim_on.prefill_cached_tokens
    );
    assert!(
        victim_on.prefill_charged_tokens
            <= prompt.len() as u64 + snap_on.resumed * BLOCK_TOKENS as u64,
        "each resume may charge at most the final partial block (charged {})",
        victim_on.prefill_charged_tokens
    );
    assert!(
        victim_on.prefill_charged_tokens < victim_off.prefill_charged_tokens,
        "the cache must strictly reduce repeat prefill charges"
    );
    assert!(snap_on.prefix_hits >= 1, "the resume admit must count as a prefix hit");
    assert!(snap_on.prefix_tokens_saved >= 2 * BLOCK_TOKENS as u64);
}

#[test]
fn queue_delay_visible_under_backlog() {
    let coord = Coordinator::start(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 40, ..Default::default() },
    );
    for i in 0..6 {
        coord.submit(vec![1, 2, 3], 40, i);
    }
    let mut last_queue = 0.0f64;
    for _ in 0..6 {
        let r = coord.collect();
        last_queue = last_queue.max(r.queue_ms);
    }
    // With a single worker the tail request must have waited.
    assert!(last_queue >= 0.0);
    coord.shutdown();
}

#[test]
fn on_complete_channel_delivers_instead_of_outbox() {
    // Completion-channel delivery (the mux server's path): every response
    // arrives on its own channel, the shared outbox stays empty, and the
    // registry still equals the sum of per-response stats.
    let coord = Coordinator::start(
        backends(2),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 64, ..Default::default() },
    );
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = coord.submit_opts(
            vec![1, 2, 3, 1 + (i as u32 % 7)],
            24,
            i,
            SubmitOpts::new().on_complete(tx),
        );
        rxs.push((id, rx));
    }
    let mut stats_sum = 0u64;
    for (id, rx) in rxs {
        let r = rx.recv().expect("response on the completion channel");
        assert_eq!(r.id, id, "each channel receives exactly its own response");
        assert_eq!(r.tokens.len(), 24);
        assert_eq!(r.status, ResponseStatus::Completed);
        stats_sum += r.stats.generated_tokens;
    }
    assert!(coord.try_collect().is_none(), "outbox must stay empty");
    let snap = coord.registry();
    assert_eq!(snap.generated_tokens, stats_sum);
    assert_eq!(snap.completed, 6);
    assert!(snap.inflight_peak >= 2, "burst submission overlaps in flight");
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}

#[test]
fn dropped_on_complete_receiver_falls_back_to_outbox() {
    // A mux connection that dies loses its receiver; the response must
    // fall back to the outbox rather than vanish (and keep the registry
    // invariant).
    let coord = Coordinator::start(
        backends(1),
        EngineId::Sps,
        EngineConfig { max_new_tokens: 32, ..Default::default() },
    );
    let (tx, rx) = std::sync::mpsc::channel();
    drop(rx);
    let id = coord.submit_opts(vec![4, 5, 6], 16, 7, SubmitOpts::new().on_complete(tx));
    let r = coord.collect_id(id);
    assert_eq!(r.tokens.len(), 16);
    let snap = coord.registry();
    assert_eq!(snap.generated_tokens, r.stats.generated_tokens);
    coord.shutdown();
}

#[test]
fn mux_style_mixed_cancel_keeps_registry_equality() {
    // Several channel-delivered streaming requests, some cancelled
    // mid-flight (the orphan-cancel path a dropped connection takes):
    // every response still arrives on its channel with partial tokens,
    // and the registry equals the per-response stats sum.
    let coord = Coordinator::start(
        backends(1),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 600, ..Default::default() },
    );
    let mut victims = Vec::new();
    let mut runners = Vec::new();
    for i in 0..2u64 {
        let (tx, rx) = std::sync::mpsc::channel();
        let (stx, srx) = std::sync::mpsc::channel();
        let id = coord.submit_opts(
            vec![1, 2, 3, 1 + i as u32],
            500,
            i,
            SubmitOpts::new().on_complete(tx).stream(stx),
        );
        victims.push((id, rx, srx));
    }
    for i in 0..2u64 {
        let (tx, rx) = std::sync::mpsc::channel();
        let id = coord.submit_opts(
            vec![4, 5, 6, 1 + i as u32],
            20,
            10 + i,
            SubmitOpts::new().on_complete(tx),
        );
        runners.push((id, rx));
    }
    let mut stats_sum = 0u64;
    for (id, rx, srx) in victims {
        // Wait for the first committed round so the cancel lands
        // mid-decode and the partial output is non-empty.
        let first = srx.recv().expect("first streamed chunk");
        assert_eq!(first.id, id);
        assert!(coord.cancel(id), "victim is live");
        let r = rx.recv().expect("cancelled response on the channel");
        assert_eq!(r.id, id);
        assert_eq!(r.status, ResponseStatus::Cancelled);
        assert_eq!(r.tokens.len() as u64, r.stats.generated_tokens);
        stats_sum += r.stats.generated_tokens;
    }
    for (id, rx) in runners {
        let r = rx.recv().expect("completed response on the channel");
        assert_eq!(r.id, id);
        assert_eq!(r.status, ResponseStatus::Completed);
        assert_eq!(r.tokens.len(), 20);
        stats_sum += r.stats.generated_tokens;
    }
    let snap = coord.registry();
    assert_eq!(snap.cancelled, 2);
    assert_eq!(snap.completed, 2);
    assert_eq!(
        snap.generated_tokens, stats_sum,
        "registry == sum of per-response stats across channel-delivered cancels"
    );
    assert_eq!(coord.pending(), 0);
    coord.shutdown();
}
