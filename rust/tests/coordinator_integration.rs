//! Coordinator under load: many requests, multiple workers, metric
//! aggregation, mixed request sizes.

use specbranch::backend::sim::{SimBackend, SimConfig};
use specbranch::backend::Backend;
use specbranch::config::{EngineConfig, EngineId, ModelPair, PairId, Task, TaskId};
use specbranch::coordinator::Coordinator;

fn backends(n: usize) -> Vec<Box<dyn Backend + Send>> {
    (0..n)
        .map(|_| {
            let cfg = SimConfig::new(
                ModelPair::get(PairId::Deepseek13b33b),
                Task::get(TaskId::HumanEval),
            );
            Box::new(SimBackend::new(cfg)) as Box<dyn Backend + Send>
        })
        .collect()
}

#[test]
fn hundred_requests_four_workers() {
    let coord = Coordinator::start(
        backends(4),
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 30, ..Default::default() },
    );
    let n = 100;
    for i in 0..n {
        coord.submit(vec![1 + (i % 50) as u32, 2, 3], 30, i);
    }
    let mut total_tokens = 0;
    for _ in 0..n {
        let r = coord.collect();
        assert_eq!(r.tokens.len(), 30);
        total_tokens += r.tokens.len();
    }
    assert_eq!(total_tokens, 30 * n as usize);
    let snap = coord.registry();
    assert_eq!(snap.completed, n);
    assert!(snap.mean_decode_ms > 0.0);
    coord.shutdown();
}

#[test]
fn mixed_lengths_complete() {
    let coord = Coordinator::start(
        backends(2),
        EngineId::Sps,
        EngineConfig { max_new_tokens: 200, ..Default::default() },
    );
    let sizes = [5usize, 50, 120, 10, 80];
    for (i, &sz) in sizes.iter().enumerate() {
        coord.submit(vec![2, 3, 4], sz, i as u64);
    }
    let mut got = std::collections::HashMap::new();
    for _ in 0..sizes.len() {
        let r = coord.collect();
        got.insert(r.id, r.tokens.len());
    }
    for (i, &sz) in sizes.iter().enumerate() {
        assert_eq!(got[&(i as u64)], sz, "request {i}");
    }
    coord.shutdown();
}

#[test]
fn queue_delay_visible_under_backlog() {
    let coord = Coordinator::start(
        backends(1),
        EngineId::Autoregressive,
        EngineConfig { max_new_tokens: 40, ..Default::default() },
    );
    for i in 0..6 {
        coord.submit(vec![1, 2, 3], 40, i);
    }
    let mut last_queue = 0.0f64;
    for _ in 0..6 {
        let r = coord.collect();
        last_queue = last_queue.max(r.queue_ms);
    }
    // With a single worker the tail request must have waited.
    assert!(last_queue >= 0.0);
    coord.shutdown();
}
