//! Server protocol round-trip over a real TCP socket (sim backend).

use specbranch::backend::sim::{SimBackend, SimConfig};
use specbranch::backend::Backend;
use specbranch::config::{EngineConfig, EngineId, ModelPair, PairId, Task, TaskId};
use specbranch::coordinator::Coordinator;
use specbranch::server::{Client, Server};

fn start_server() -> std::net::SocketAddr {
    let backends: Vec<Box<dyn Backend + Send>> = (0..2)
        .map(|_| {
            let cfg = SimConfig::new(
                ModelPair::get(PairId::Llama68m7b),
                Task::get(TaskId::MtBench),
            );
            Box::new(SimBackend::new(cfg)) as Box<dyn Backend + Send>
        })
        .collect();
    let coord = Coordinator::start(
        backends,
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 32, ..Default::default() },
    );
    let server = Server::bind("127.0.0.1:0", coord).expect("bind");
    let addr = server.local_addr();
    std::thread::spawn(move || server.serve(None));
    addr
}

#[test]
fn generate_roundtrip() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let reply = client.generate("hello world this is a test", 32).expect("generate");
    assert!(!reply.text.is_empty());
    let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(gen, 32.0, "per-request budget honored exactly");
    let tps = reply.stats.get("tokens_per_sec").and_then(|v| v.as_f64()).unwrap();
    assert!(tps > 0.0);
    client.quit().unwrap();
}

#[test]
fn streaming_roundtrip_concatenates_to_completion() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let (reply, parts) = client
        .generate_stream("stream me some tokens please", 24)
        .expect("generate_stream");
    assert!(!parts.is_empty(), "streaming must deliver per-round chunks");
    let joined: String = parts.concat();
    assert_eq!(joined, reply.text, "PART chunks must concatenate to OK text");
    let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(gen, 24.0);
    client.quit().unwrap();
}

#[test]
fn metrics_accumulate() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    for _ in 0..3 {
        client.generate("some prompt text", 16).expect("generate");
    }
    let m = client.metrics().expect("metrics");
    let completed = m.get("completed").and_then(|v| v.as_f64()).unwrap();
    assert!(completed >= 3.0);
    // The preemption counters are present (zero without --preempt).
    assert_eq!(m.get("preemptions").and_then(|v| v.as_f64()), Some(0.0));
    assert_eq!(m.get("resumed").and_then(|v| v.as_f64()), Some(0.0));
    client.quit().unwrap();
}

#[test]
fn metrics_reply_on_idle_server_is_total() {
    // Empty registry: every counter is 0 and every derived ratio is a
    // finite 0.0 — the reply must parse (a NaN would break the json).
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let m = client.metrics().expect("idle METRICS must stay parseable");
    for key in [
        "completed",
        "cancelled",
        "generated_tokens",
        "rounds",
        "admission_deferrals",
        "batched_rounds",
        "fused_requests",
        "preemptions",
        "resumed",
        "repeat_prefill_tokens",
        "kv_reclaimed_bytes",
        "mean_fused_width",
        "mean_repeat_prefill_tokens",
        "mean_queue_ms",
        "mean_decode_ms",
    ] {
        let v = m.get(key).and_then(|v| v.as_f64());
        assert_eq!(v, Some(0.0), "{key} must be a finite 0 on an idle server");
    }
    client.quit().unwrap();
}

#[test]
fn multiple_clients_share_server() {
    let addr = start_server();
    let h: Vec<_> = (0..3)
        .map(|i| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let r = c.generate(&format!("client {i} prompt"), 16).expect("gen");
                assert!(!r.text.is_empty());
            })
        })
        .collect();
    for t in h {
        t.join().unwrap();
    }
}

#[test]
fn priority_and_deadline_options_roundtrip() {
    use specbranch::util::json::Value;
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let reply = client
        .generate_opts("a prompt with scheduling options", 16, 3, Some(60_000))
        .expect("generate_opts");
    let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(gen, 16.0);
    assert_eq!(
        reply.stats.get("cancelled"),
        Some(&Value::Bool(false)),
        "completed request reports cancelled=false"
    );
    assert_eq!(
        reply.stats.get("deadline_met"),
        Some(&Value::Bool(true)),
        "a 60s deadline on a 16-token request is met"
    );
    // Without a deadline the verdict is null.
    let reply = client.generate_opts("no deadline here", 8, 0, None).expect("gen");
    assert_eq!(reply.stats.get("deadline_met"), Some(&Value::Null));
    client.quit().unwrap();
}

#[test]
fn cancel_from_second_connection_returns_partial() {
    use std::io::{BufRead, BufReader, Write};
    let addr = start_server();
    // Open the cancel connection first so cancellation is a single write
    // once the stream starts.
    let mut canceller = Client::connect(&addr.to_string()).expect("connect canceller");
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    // A budget far larger than one round so cancellation cannot race
    // completion (the sim KV capacity bounds it anyway).
    writeln!(s, "GENS 8000 stream a very long generation").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let part = line.strip_prefix("PART ").expect("first streamed chunk");
    let id: u64 = part.split_whitespace().next().unwrap().parse().unwrap();
    assert!(canceller.cancel(id).expect("cancel roundtrip"), "request is live");
    // Drain PART lines until the OK carrying the partial completion.
    let ok_line = loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if !line.starts_with("PART ") {
            break line.clone();
        }
    };
    assert!(ok_line.starts_with("OK "), "got: {ok_line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("STATS "), "got: {line}");
    assert!(
        line.contains("\"cancelled\": true"),
        "stats must flag the cancellation: {line}"
    );
    // Cancelling again misses: the request already finished.
    assert!(!canceller.cancel(id).expect("second cancel"));
    canceller.quit().unwrap();
}

#[test]
fn bad_commands_get_errors_not_disconnects() {
    use std::io::{BufRead, BufReader, Write};
    let addr = start_server();
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    writeln!(s, "NOPE").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR"));
    writeln!(s, "GEN abc").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR") || line.contains("bad"));
}
