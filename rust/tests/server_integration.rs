//! Server protocol round-trip over a real TCP socket (sim backend).

use specbranch::backend::sim::{SimBackend, SimConfig};
use specbranch::backend::Backend;
use specbranch::config::{EngineConfig, EngineId, ModelPair, PairId, Task, TaskId};
use specbranch::coordinator::Coordinator;
use specbranch::server::{Client, Server};

fn start_server() -> std::net::SocketAddr {
    let backends: Vec<Box<dyn Backend + Send>> = (0..2)
        .map(|_| {
            let cfg = SimConfig::new(
                ModelPair::get(PairId::Llama68m7b),
                Task::get(TaskId::MtBench),
            );
            Box::new(SimBackend::new(cfg)) as Box<dyn Backend + Send>
        })
        .collect();
    let coord = Coordinator::start(
        backends,
        EngineId::SpecBranch,
        EngineConfig { max_new_tokens: 32, ..Default::default() },
    );
    let server = Server::bind("127.0.0.1:0", coord).expect("bind");
    let addr = server.local_addr();
    std::thread::spawn(move || server.serve(None));
    addr
}

#[test]
fn generate_roundtrip() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let reply = client.generate("hello world this is a test", 32).expect("generate");
    assert!(!reply.text.is_empty());
    let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(gen, 32.0, "per-request budget honored exactly");
    let tps = reply.stats.get("tokens_per_sec").and_then(|v| v.as_f64()).unwrap();
    assert!(tps > 0.0);
    client.quit().unwrap();
}

#[test]
fn streaming_roundtrip_concatenates_to_completion() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let (reply, parts) = client
        .generate_stream("stream me some tokens please", 24)
        .expect("generate_stream");
    assert!(!parts.is_empty(), "streaming must deliver per-round chunks");
    let joined: String = parts.concat();
    assert_eq!(joined, reply.text, "PART chunks must concatenate to OK text");
    let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(gen, 24.0);
    client.quit().unwrap();
}

#[test]
fn metrics_accumulate() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    for _ in 0..3 {
        client.generate("some prompt text", 16).expect("generate");
    }
    let m = client.metrics().expect("metrics");
    let completed = m.get("completed").and_then(|v| v.as_f64()).unwrap();
    assert!(completed >= 3.0);
    // The preemption counters are present (zero without --preempt).
    assert_eq!(m.get("preemptions").and_then(|v| v.as_f64()), Some(0.0));
    assert_eq!(m.get("resumed").and_then(|v| v.as_f64()), Some(0.0));
    client.quit().unwrap();
}

#[test]
fn metrics_reply_on_idle_server_is_total() {
    // Empty registry: every counter is 0 and every derived ratio is a
    // finite 0.0 — the reply must parse (a NaN would break the json).
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let m = client.metrics().expect("idle METRICS must stay parseable");
    for key in [
        "completed",
        "cancelled",
        "generated_tokens",
        "rounds",
        "admission_deferrals",
        "batched_rounds",
        "fused_requests",
        "preemptions",
        "resumed",
        "repeat_prefill_tokens",
        "kv_reclaimed_bytes",
        "mean_fused_width",
        "mean_repeat_prefill_tokens",
        "mean_queue_ms",
        "mean_decode_ms",
    ] {
        let v = m.get(key).and_then(|v| v.as_f64());
        assert_eq!(v, Some(0.0), "{key} must be a finite 0 on an idle server");
    }
    client.quit().unwrap();
}

#[test]
fn multiple_clients_share_server() {
    let addr = start_server();
    let h: Vec<_> = (0..3)
        .map(|i| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let r = c.generate(&format!("client {i} prompt"), 16).expect("gen");
                assert!(!r.text.is_empty());
            })
        })
        .collect();
    for t in h {
        t.join().unwrap();
    }
}

#[test]
fn priority_and_deadline_options_roundtrip() {
    use specbranch::util::json::Value;
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let reply = client
        .generate_opts("a prompt with scheduling options", 16, 3, Some(60_000))
        .expect("generate_opts");
    let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
    assert_eq!(gen, 16.0);
    assert_eq!(
        reply.stats.get("cancelled"),
        Some(&Value::Bool(false)),
        "completed request reports cancelled=false"
    );
    assert_eq!(
        reply.stats.get("deadline_met"),
        Some(&Value::Bool(true)),
        "a 60s deadline on a 16-token request is met"
    );
    // Without a deadline the verdict is null.
    let reply = client.generate_opts("no deadline here", 8, 0, None).expect("gen");
    assert_eq!(reply.stats.get("deadline_met"), Some(&Value::Null));
    client.quit().unwrap();
}

#[test]
fn cancel_from_second_connection_returns_partial() {
    use std::io::{BufRead, BufReader, Write};
    let addr = start_server();
    // Open the cancel connection first so cancellation is a single write
    // once the stream starts.
    let mut canceller = Client::connect(&addr.to_string()).expect("connect canceller");
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    // A budget far larger than one round so cancellation cannot race
    // completion (the sim KV capacity bounds it anyway).
    writeln!(s, "GENS 8000 stream a very long generation").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let part = line.strip_prefix("PART ").expect("first streamed chunk");
    let id: u64 = part.split_whitespace().next().unwrap().parse().unwrap();
    assert!(canceller.cancel(id).expect("cancel roundtrip"), "request is live");
    // Drain PART lines until the OK carrying the partial completion.
    let ok_line = loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if !line.starts_with("PART ") {
            break line.clone();
        }
    };
    assert!(ok_line.starts_with("OK "), "got: {ok_line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("STATS "), "got: {line}");
    assert!(
        line.contains("\"cancelled\": true"),
        "stats must flag the cancellation: {line}"
    );
    // Cancelling again misses: the request already finished.
    assert!(!canceller.cancel(id).expect("second cancel"));
    canceller.quit().unwrap();
}

#[test]
fn bad_commands_get_errors_not_disconnects() {
    use std::io::{BufRead, BufReader, Write};
    let addr = start_server();
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    writeln!(s, "NOPE").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR"));
    writeln!(s, "GEN abc").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR") || line.contains("bad"));
}

#[test]
fn mux_one_connection_keeps_many_requests_inflight() {
    // The tentpole acceptance check: one connection with 8 tagged requests
    // submitted back-to-back keeps ≥ 2 of them concurrently in flight in
    // the coordinator (inflight_peak in the registry snapshot), and every
    // reply routes to its own tag.
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    let inflight = 8usize;
    for i in 0..inflight {
        client
            .submit(&format!("t{i}"), &format!("mux prompt number {i} here"), 24)
            .expect("submit");
    }
    // Await out of submission order on purpose: frames for other tags
    // must buffer, not get lost.
    for i in (0..inflight).rev() {
        let (reply, parts) = client.await_reply(&format!("t{i}")).expect("await");
        assert_eq!(reply.tag.as_deref(), Some(format!("t{i}").as_str()));
        assert!(parts.is_empty(), "GEN (non-streaming) sends no PART frames");
        assert!(!reply.text.is_empty());
        let gen = reply.stats.get("generated").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(gen, 24.0, "per-request budget honored under mux");
    }
    let m = client.metrics().expect("metrics");
    let peak = m.get("inflight_peak").and_then(|v| v.as_f64()).unwrap();
    assert!(peak >= 2.0, "one mux connection must overlap requests, peak {peak}");
    let completed = m.get("completed").and_then(|v| v.as_f64()).unwrap();
    assert!(completed >= inflight as f64);
    // A retired tag is reusable.
    client.submit("t0", "reuse the first tag", 8).expect("resubmit");
    let (reply, _) = client.await_reply("t0").expect("await reuse");
    assert_eq!(reply.stats.get("generated").and_then(|v| v.as_f64()), Some(8.0));
    client.quit().unwrap();
}

#[test]
fn mux_interleaved_streams_reassemble_byte_identical() {
    // Serial references first (fresh connection each, one at a time),
    // then the same prompts streamed concurrently on ONE connection: the
    // per-tag PART reassembly and final text must match byte-for-byte.
    let addr = start_server();
    let n = 3usize;
    let prompt = |i: usize| format!("interleave source text {i} for the stream");
    let mut reference = Vec::new();
    for i in 0..n {
        let mut c = Client::connect(&addr.to_string()).expect("connect serial");
        let (reply, parts) = c.generate_stream(&prompt(i), 28).expect("serial stream");
        assert_eq!(parts.concat(), reply.text);
        reference.push(reply.text);
        c.quit().unwrap();
    }
    let mut client = Client::connect(&addr.to_string()).expect("connect mux");
    for i in 0..n {
        client.submit_stream(&format!("s{i}"), &prompt(i), 28).expect("submit");
    }
    // Drive the raw event stream: PART frames of the three requests
    // interleave in wire order; reassemble per tag.
    let mut parts: std::collections::HashMap<String, String> = Default::default();
    let mut finals: std::collections::HashMap<String, String> = Default::default();
    while finals.len() < n {
        match client.next_event().expect("event") {
            specbranch::server::MuxEvent::Part { tag, text } => {
                parts.entry(tag).or_default().push_str(&text);
            }
            specbranch::server::MuxEvent::Done { tag, reply } => {
                finals.insert(tag, reply.text);
            }
            other => panic!("unexpected frame: {other:?}"),
        }
    }
    for i in 0..n {
        let tag = format!("s{i}");
        assert_eq!(finals[&tag], reference[i], "final text matches serial reference");
        assert_eq!(parts[&tag], reference[i], "PART reassembly matches serial reference");
    }
    client.quit().unwrap();
}

#[test]
fn mux_same_connection_cancel_returns_tagged_partial() {
    let addr = start_server();
    let mut client = Client::connect(&addr.to_string()).expect("connect");
    client
        .submit_stream("big", "stream a very long generation please", 8000)
        .expect("submit");
    // Wait for the first committed round so the cancel lands mid-decode.
    match client.next_event().expect("event") {
        specbranch::server::MuxEvent::Part { tag, .. } => assert_eq!(tag, "big"),
        other => panic!("unexpected frame before first PART: {other:?}"),
    }
    assert!(client.cancel_tag("big").expect("cancel"), "request is live");
    let (reply, parts) = client.await_reply("big").expect("await cancelled");
    assert_eq!(reply.tag.as_deref(), Some("big"));
    assert_eq!(
        reply.stats.get("cancelled"),
        Some(&specbranch::util::json::Value::Bool(true)),
        "stats must flag the cancellation"
    );
    assert!(!reply.text.is_empty(), "partial tokens committed before cancel");
    assert_eq!(parts.concat(), reply.text, "buffered + live PART frames reassemble");
    // Cancelling a retired tag misses.
    assert!(!client.cancel_tag("big").expect("second cancel"));
    client.quit().unwrap();
}

#[test]
fn dropped_mux_connection_cancels_orphans() {
    use std::io::{BufRead, BufReader, Write};
    let addr = start_server();
    {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        writeln!(s, "GENS a 4000 orphaned stream one").unwrap();
        writeln!(s, "GENS b 4000 orphaned stream two").unwrap();
        // Wait until decode demonstrably started, then drop the socket
        // with both requests mid-flight.
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("PART "), "got: {line}");
    }
    // The server must cancel both orphans; their partial tokens stay
    // counted (the registry invariant is asserted inside the coordinator).
    let mut probe = Client::connect(&addr.to_string()).expect("connect probe");
    let mut cancelled = 0.0;
    for _ in 0..400 {
        let m = probe.metrics().expect("metrics");
        cancelled = m.get("cancelled").and_then(|v| v.as_f64()).unwrap();
        if cancelled >= 2.0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    assert_eq!(cancelled, 2.0, "both orphaned requests must be cancelled");
    let m = probe.metrics().expect("metrics");
    let generated = m.get("generated_tokens").and_then(|v| v.as_f64()).unwrap();
    assert!(generated > 0.0, "partial tokens of the orphans are counted");
    probe.quit().unwrap();
}

/// Write one raw frame and read one raw reply line (trimmed).
fn raw_roundtrip(
    s: &mut std::net::TcpStream,
    reader: &mut std::io::BufReader<std::net::TcpStream>,
    req: &str,
) -> String {
    use std::io::{BufRead, Write};
    writeln!(s, "{req}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

#[test]
fn v1_error_strings_are_pinned() {
    // The untagged v1 error strings are a compatibility contract:
    // byte-for-byte what the pre-v2 server replied.
    let addr = start_server();
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = std::io::BufReader::new(s.try_clone().unwrap());
    assert_eq!(raw_roundtrip(&mut s, &mut reader, "NOPE"), "ERR unknown command");
    assert_eq!(
        raw_roundtrip(&mut s, &mut reader, "GEN 12"),
        "ERR GEN needs '<max_new> <prompt>'"
    );
    assert_eq!(raw_roundtrip(&mut s, &mut reader, "CANCEL not an id"), "ERR bad cancel id");
}

#[test]
fn v2_errors_echo_the_tag() {
    use std::io::Write;
    let addr = start_server();
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = std::io::BufReader::new(s.try_clone().unwrap());
    assert_eq!(raw_roundtrip(&mut s, &mut reader, "GEN t1 abc hello"), "ERR t1 bad max_new");
    assert_eq!(
        raw_roundtrip(&mut s, &mut reader, "GEN t2"),
        "ERR t2 GEN needs '<max_new> <prompt>'"
    );
    // A live tag may not be reused: submit a slow request, then reuse its
    // tag — the error must name the tag so the mux client can attribute it.
    writeln!(s, "GEN busy 2000 a long running generation").unwrap();
    assert_eq!(
        raw_roundtrip(&mut s, &mut reader, "GEN busy 10 short one"),
        "ERR busy tag already in flight"
    );
}
