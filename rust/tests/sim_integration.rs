//! Integration over the simulation backend: cross-engine invariants at a
//! scale unit tests don't reach, plus end-to-end metric sanity.

use specbranch::backend::sim::{SimBackend, SimConfig};
use specbranch::backend::Backend;
use specbranch::config::{EngineConfig, EngineId, ModelPair, PairId, Task, TaskId};
use specbranch::engines::{self, Engine};
use specbranch::metrics::DecodeStats;
use specbranch::util::prng::Pcg32;

fn run(pair: PairId, task: TaskId, engine: EngineId, seed: u64, n: usize) -> DecodeStats {
    let cfg = SimConfig::new(ModelPair::get(pair), Task::get(task));
    let backend = SimBackend::new(cfg);
    let e = engines::build(
        engine,
        EngineConfig {
            gamma: (ModelPair::get(pair).c as usize).min(8),
            max_new_tokens: n,
            ..Default::default()
        },
    );
    let mut s = backend.new_session(seed);
    e.generate(s.as_mut(), &[1, 2, 3, 4], &mut Pcg32::new(seed)).stats
}

#[test]
fn every_engine_terminates_on_every_pair() {
    for pair in ModelPair::PAPER_PAIRS {
        for engine in [
            EngineId::Autoregressive,
            EngineId::Sps,
            EngineId::AdaEdl,
            EngineId::Lookahead,
            EngineId::Pearl,
            EngineId::SpecBranch,
            EngineId::SpecBranchNoBranch,
            EngineId::SpecBranchNoHrad,
            EngineId::SpecBranchPp,
        ] {
            let stats = run(pair, TaskId::Qa, engine, 3, 60);
            assert!(
                stats.generated_tokens >= 60,
                "{engine:?} on {pair:?}: only {} tokens",
                stats.generated_tokens
            );
            assert!(stats.elapsed_ms > 0.0);
        }
    }
}

#[test]
fn speculative_engines_never_lose_tokens() {
    // generated == committed − prompt: every commit is accounted.
    for engine in [EngineId::Sps, EngineId::Pearl, EngineId::SpecBranch] {
        let stats = run(PairId::Vicuna68m13b, TaskId::MtBench, engine, 11, 150);
        assert!(stats.generated_tokens >= 150);
        assert!(stats.rounds > 0);
        // M is bounded by block size + bonus.
        assert!(stats.mean_accepted() <= 18.0);
    }
}

#[test]
fn all_accept_condition_tracks_alignment() {
    // Well-aligned pairs see far more all-accept rounds (the condition
    // parallel SD needs, §1).
    let poor = run(PairId::Vicuna68m13b, TaskId::CnnDm, EngineId::Sps, 5, 250);
    let good = run(PairId::Llama318b70b, TaskId::HumanEval, EngineId::Sps, 5, 250);
    let frac = |s: &DecodeStats| s.all_accept_rounds as f64 / s.rounds.max(1) as f64;
    assert!(
        frac(&good) > frac(&poor),
        "good {:.2} vs poor {:.2}",
        frac(&good),
        frac(&poor)
    );
}

#[test]
fn task_difficulty_ordering_holds() {
    // Translation (easy) must yield higher SpS speedup than CNN/DM (hard)
    // on the same pair — the per-task calibration of Tables 2/3.
    let pair = PairId::Llama68m7b;
    let easy = run(pair, TaskId::Translation, EngineId::Sps, 9, 250);
    let hard = run(pair, TaskId::CnnDm, EngineId::Sps, 9, 250);
    let easy_ar = run(pair, TaskId::Translation, EngineId::Autoregressive, 9, 250);
    let hard_ar = run(pair, TaskId::CnnDm, EngineId::Autoregressive, 9, 250);
    assert!(easy.speedup_vs(&easy_ar) > hard.speedup_vs(&hard_ar));
}

#[test]
fn energy_ordering_matches_paper_on_poor_alignment() {
    // Table 10: SpecBranch < SpS < PEARL on poorly aligned pairs.
    use specbranch::metrics::energy_kj;
    let pair = ModelPair::get(PairId::Vicuna68m13b);
    let sps = energy_kj(&run(PairId::Vicuna68m13b, TaskId::HumanEval, EngineId::Sps, 3, 300), &pair);
    let pearl = energy_kj(&run(PairId::Vicuna68m13b, TaskId::HumanEval, EngineId::Pearl, 3, 300), &pair);
    let ours = energy_kj(&run(PairId::Vicuna68m13b, TaskId::HumanEval, EngineId::SpecBranch, 3, 300), &pair);
    assert!(ours < pearl, "SpecBranch {ours:.2} kJ vs PEARL {pearl:.2} kJ");
    let _ = sps;
}

#[test]
fn deterministic_given_seed() {
    let a = run(PairId::Deepseek13b33b, TaskId::Math, EngineId::SpecBranch, 21, 100);
    let b = run(PairId::Deepseek13b33b, TaskId::Math, EngineId::SpecBranch, 21, 100);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.elapsed_ms, b.elapsed_ms);
}
