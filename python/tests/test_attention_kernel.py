"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle,
including hypothesis sweeps over shapes/dtypes (the CORE L1 signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def run_both(h, tq, s, d, cur_len, block_k=128, dtype=jnp.float32, seed=0):
    q = rand(seed, (h, tq, d), dtype)
    k = rand(seed + 1, (h, s, d), dtype)
    v = rand(seed + 2, (h, s, d), dtype)
    bias = A.decode_bias(tq, s, cur_len)
    got = A.attention(q, k, v, bias, block_k=block_k)
    want = ref.attention_ref(q, k, v, bias)
    return np.asarray(got), np.asarray(want)


def test_decode_shape_matches_ref():
    got, want = run_both(4, 1, 160, 32, cur_len=37)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_verify_block_matches_ref():
    got, want = run_both(4, 9, 160, 32, cur_len=80)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_non_multiple_kv_length_pads():
    got, want = run_both(2, 3, 100, 16, cur_len=50)  # 100 % 128 != 0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_small_block_k_tiling():
    got, want = run_both(2, 4, 64, 16, cur_len=30, block_k=16)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cur_len_zero_masks_history():
    # Only the query's own (causal) positions are visible.
    got, want = run_both(2, 2, 32, 8, cur_len=0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bf16_inputs_close_to_f32_ref():
    q = rand(5, (2, 2, 16), jnp.bfloat16)
    k = rand(6, (2, 64, 16), jnp.bfloat16)
    v = rand(7, (2, 64, 16), jnp.bfloat16)
    bias = A.decode_bias(2, 64, 20)
    got = np.asarray(A.attention(q, k, v, bias, block_k=32))
    want = np.asarray(ref.attention_ref(q, k, v, bias))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_decode_bias_semantics():
    b = np.asarray(A.decode_bias(3, 8, 2))
    # Row i sits at position 2+i: may see columns <= 2+i.
    for i in range(3):
        for j in range(8):
            visible = j <= 2 + i
            assert (b[i, j] == 0.0) == visible, (i, j)


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 4),
    tq=st.integers(1, 9),
    d=st.sampled_from([8, 16, 32]),
    s_blocks=st.integers(1, 3),
    block_k=st.sampled_from([16, 32, 128]),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shapes(h, tq, d, s_blocks, block_k, frac, seed):
    s = block_k * s_blocks
    cur_len = min(int(frac * (s - tq)), s - tq)
    got, want = run_both(h, tq, s, d, cur_len, block_k=block_k, seed=seed)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
