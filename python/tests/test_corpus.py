"""Corpus generator: determinism and learnability structure."""

import numpy as np

from compile import common, corpus


def test_deterministic():
    a = corpus.sample_tokens(3, 500)
    b = corpus.sample_tokens(3, 500)
    np.testing.assert_array_equal(a, b)


def test_tokens_in_vocab():
    t = corpus.sample_tokens(1, 1000)
    assert t.min() >= 0 and t.max() < common.VOCAB


def test_chain_is_predictable():
    """An order-2 oracle should predict most next tokens (the corpus must
    be learnable, else the draft/target pair cannot align)."""
    succ, probs = corpus.build_chain(0)
    toks = corpus.sample_tokens(0, 3000)
    hits = 0
    for i in range(2, len(toks)):
        a, b = toks[i - 2], toks[i - 1]
        top = succ[a, b, np.argmax(probs[a, b])]
        hits += int(top == toks[i])
    rate = hits / (len(toks) - 2)
    assert rate > 0.5, f"top-1 predictability {rate}"


def test_batches_shapes():
    toks = corpus.sample_tokens(2, 5000)
    it = corpus.batches(toks, batch=4, seq=16, seed=0)
    b = next(it)
    assert b.shape == (4, 17)


def test_prompts_are_windows():
    toks = corpus.sample_tokens(2, 5000)
    ps = corpus.prompts(toks, 5, 12, 0)
    assert len(ps) == 5
    assert all(len(p) == 12 for p in ps)
