"""L1 correctness: fused FFN Pallas kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ffn as F
from compile.kernels import ref


def make(seed, t, d, dff):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (t, d))
    w1 = jax.random.normal(ks[1], (d, dff)) * d ** -0.5
    b1 = jax.random.normal(ks[2], (dff,)) * 0.1
    w2 = jax.random.normal(ks[3], (dff, d)) * dff ** -0.5
    b2 = jax.random.normal(ks[4], (d,)) * 0.1
    return x, w1, b1, w2, b2


def test_matches_ref_exact_tile():
    args = make(0, 8, 64, 128)
    np.testing.assert_allclose(
        np.asarray(F.ffn(*args)), np.asarray(ref.ffn_ref(*args)),
        rtol=1e-5, atol=1e-5)


def test_matches_ref_ragged_rows():
    args = make(1, 9, 128, 256)  # 9 % 8 != 0 -> pad path
    np.testing.assert_allclose(
        np.asarray(F.ffn(*args)), np.asarray(ref.ffn_ref(*args)),
        rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 24),
    d=st.sampled_from([16, 64, 128]),
    dff=st.sampled_from([32, 128, 256]),
    block_t=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_shapes(t, d, dff, block_t, seed):
    args = make(seed, t, d, dff)
    got = np.asarray(F.ffn(*args, block_t=block_t))
    want = np.asarray(ref.ffn_ref(*args))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
