"""H-RAD MLP training sanity: class balance handling, convergence, and the
labelling rule used to harvest traces."""

import numpy as np
import jax.numpy as jnp

from compile import common, hrad


def synth_dataset(n=600, seed=0):
    """Linearly separable 3-class features so training must succeed."""
    rng = np.random.default_rng(seed)
    cfg = common.HRAD
    feats = rng.normal(size=(n, cfg.k_layers * cfg.d_model)).astype(np.float32)
    labels = rng.integers(0, 3, size=n).astype(np.int32)
    # Plant a strong signal in the first two dims.
    feats[:, 0] = (labels == 0) * 3.0 + rng.normal(size=n) * 0.1
    feats[:, 1] = (labels == 2) * 3.0 + rng.normal(size=n) * 0.1
    toks = rng.integers(0, common.VOCAB, size=n).astype(np.int32)
    return feats, toks, labels


def test_mlp_learns_separable_classes():
    feats, toks, labels = synth_dataset()
    emb = jnp.zeros((common.VOCAB, common.HRAD.d_emb), jnp.float32)
    mlp, acc = hrad.train_mlp(common.HRAD, emb, feats, toks, labels,
                              epochs=12, log=None)
    assert acc > 0.9, f"accuracy {acc}"


def test_confusion_matrix_shape_and_mass():
    feats, toks, labels = synth_dataset(n=300, seed=1)
    emb = np.zeros((common.VOCAB, common.HRAD.d_emb), np.float32)
    z = np.concatenate([feats, emb[toks]], axis=1)
    mlp = hrad.init_mlp(common.HRAD)
    cm = hrad.confusion(mlp, z, labels)
    assert cm.shape == (3, 3)
    assert cm.sum() == 300


def test_class_weighting_handles_imbalance():
    feats, toks, labels = synth_dataset(n=600, seed=2)
    # Make class 2 rare (the paper's SMOTE scenario).
    keep = (labels != 2) | (np.arange(len(labels)) % 10 == 0)
    feats, toks, labels = feats[keep], toks[keep], labels[keep]
    emb = jnp.zeros((common.VOCAB, common.HRAD.d_emb), jnp.float32)
    mlp, _ = hrad.train_mlp(common.HRAD, emb, feats, toks, labels,
                            epochs=12, log=None)
    z = np.concatenate([feats, np.zeros((len(toks), common.HRAD.d_emb), np.float32)], axis=1)
    cm = hrad.confusion(mlp, z, labels)
    rare_recall = cm[2, 2] / max(cm[2].sum(), 1)
    assert rare_recall > 0.5, f"rare-class recall {rare_recall}"


def test_mlp_logits_shape():
    mlp = hrad.init_mlp(common.HRAD)
    z = jnp.zeros((5, common.HRAD.d_in))
    out = hrad.mlp_logits(mlp, z)
    assert out.shape == (5, 3)
