"""L2 correctness: the transformer decode/verify step.

* pallas path == ref path on the full step (kernel integration);
* KV-cache semantics: incremental decode == full-sequence forward;
* rollback contract: slots >= cur_len are dead.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model

CFG = common.ModelConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                         d_head=16, d_ff=64, vocab=common.VOCAB, seq_max=48)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, 0)


def step(params, tokens, kv, cur_len, pallas=False):
    return model.step(params, CFG, jnp.asarray(tokens, jnp.int32), kv,
                      jnp.int32(cur_len), use_pallas=pallas)


def test_pallas_and_ref_steps_agree(params):
    kv = model.empty_kv(CFG)
    lr, hr, kvr = step(params, [1, 2, 3, 4], kv, 0, pallas=False)
    lp, hp, kvp = step(params, [1, 2, 3, 4], kv, 0, pallas=True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(kvr), np.asarray(kvp), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hp), rtol=2e-4, atol=2e-4)


def test_incremental_equals_block(params):
    """Feeding tokens one-by-one must equal feeding them as one block."""
    toks = [5, 9, 13, 21, 34]
    kv = model.empty_kv(CFG)
    lb, _, _ = step(params, toks, kv, 0)
    kv_inc = model.empty_kv(CFG)
    logits_last = None
    for i, t in enumerate(toks):
        logits_last, _, kv_inc = step(params, [t], kv_inc, i)
    np.testing.assert_allclose(
        np.asarray(lb[-1]), np.asarray(logits_last[0]), rtol=1e-4, atol=1e-4)


def test_rollback_slots_are_dead(params):
    """Writing garbage at positions >= cur_len must not affect outputs."""
    kv = model.empty_kv(CFG)
    _, _, kv = step(params, [1, 2, 3], kv, 0)
    # Poison slots beyond 3.
    poisoned = kv.at[:, :, :, 3:, :].set(1e9)
    l1, _, _ = step(params, [4], kv, 3)
    l2, _, _ = step(params, [4], poisoned, 3)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5)


def test_train_forward_matches_step(params):
    """The training-time forward and the cached step agree on logits."""
    toks = [3, 7, 11, 19]
    full = model.forward_train(params, CFG, jnp.asarray([toks], jnp.int32))
    kv = model.empty_kv(CFG)
    blk, _, _ = step(params, toks, kv, 0)
    np.testing.assert_allclose(
        np.asarray(full[0]), np.asarray(blk), rtol=1e-4, atol=1e-4)


def test_hiddens_shape(params):
    kv = model.empty_kv(CFG)
    _, hid, _ = step(params, [1, 2], kv, 0)
    assert hid.shape == (2, min(common.HRAD_K, CFG.n_layers) * CFG.d_model)


def test_xent_loss_finite(params):
    batch = jnp.asarray(np.random.default_rng(0).integers(0, CFG.vocab, (2, 9)))
    loss = model.xent_loss(params, CFG, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
