import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root.
HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.dirname(HERE))
