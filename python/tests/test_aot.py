"""AOT export self-check: HLO text round-trips and matches the manifest.
Runs against the cached artifacts when present (fast); otherwise exports a
minimal function to a temp dir."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, common, model


ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))), "artifacts")


def test_hlo_text_contains_full_constants(tmp_path):
    cfg = common.ModelConfig(name="m", n_layers=1, d_model=16, n_heads=2,
                             d_head=8, d_ff=32, vocab=16, seq_max=24)
    params = model.init_params(cfg, 0)
    fn, specs = model.make_step_fn(params, cfg, 1, use_pallas=True)
    path = tmp_path / "m.hlo.txt"
    aot.lower_and_write(fn, specs, str(path), log=lambda *a: None)
    text = path.read_text()
    assert "ENTRY" in text
    assert "{...}" not in text, "large constants must not be elided"


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_configs():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    assert man["vocab"] == common.VOCAB
    assert man["seq_max"] == common.SEQ_MAX
    assert man["block"] == common.GAMMA_MAX + 1
    for ep, spec in man["entry_points"].items():
        path = os.path.join(ART, spec["file"])
        assert os.path.exists(path), f"{ep} artifact missing"


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "params_target.npz")),
                    reason="artifacts not built")
def test_trained_pair_has_capacity_gap():
    """The draft must be measurably weaker than the target (that is the
    whole point of the pair), but both must beat the uniform baseline."""
    from compile import corpus, train
    t_params = train.load_params(os.path.join(ART, "params_target.npz"),
                                 model.init_params(common.TARGET, 0))
    d_params = train.load_params(os.path.join(ART, "params_draft.npz"),
                                 model.init_params(common.DRAFT, 1))
    toks = corpus.sample_tokens(0, 4000)
    batch = jnp.asarray(toks[:33 * 8].reshape(8, 33))
    t_loss = float(model.xent_loss(t_params, common.TARGET, batch))
    d_loss = float(model.xent_loss(d_params, common.DRAFT, batch))
    uniform = np.log(common.VOCAB)
    assert t_loss < d_loss < uniform, (t_loss, d_loss, uniform)
