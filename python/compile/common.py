"""Shared configuration for the SpecBranch compile path (L1 + L2).

Everything here is build-time only: these configs describe the tiny
draft/target transformer pair that stands in for the paper's model pairs
(see DESIGN.md §3), the AOT shape contract consumed by the Rust runtime,
and deterministic PRNG helpers.
"""

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shape contract shared with rust/src/runtime (see artifacts/manifest.json).
# ---------------------------------------------------------------------------

VOCAB = 64           # symbol alphabet (small enough that the order-2 corpus
                     # chain is actually learnable from a ~240k-token corpus)
SEQ_MAX = 160        # static KV-cache length (PJRT requires fixed shapes)
GAMMA_MAX = 8        # max draft tokens verified in a single target call
HRAD_K = 4           # number of trailing target layers feeding H-RAD
HRAD_CLASSES = 3     # {0: all-reject, 1: use-confidence, 2: all-accept}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of one decoder-only transformer."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_head: int
    d_ff: int
    vocab: int = VOCAB
    seq_max: int = SEQ_MAX

    @property
    def kv_shape(self):
        """KV cache shape threaded through every decode/verify call."""
        return (self.n_layers, 2, self.n_heads, self.seq_max, self.d_head)

    def to_dict(self):
        return asdict(self)


# The "paper pair": target plays the large model, draft the small one. The
# draft is deliberately lower-capacity (fewer layers, narrower) so that after
# training on the same corpus its distribution only partially matches the
# target's -- that mismatch is exactly what produces realistic acceptance
# rates for speculative decoding.
TARGET = ModelConfig(name="target", n_layers=4, d_model=128, n_heads=4,
                     d_head=32, d_ff=256)
DRAFT = ModelConfig(name="draft", n_layers=2, d_model=64, n_heads=4,
                    d_head=16, d_ff=128)


@dataclass(frozen=True)
class HradConfig:
    """H-RAD 3-class MLP (paper Eq. 4-6, App. E.4)."""

    k_layers: int = HRAD_K          # K hidden states from the target
    d_model: int = TARGET.d_model
    d_emb: int = DRAFT.d_model      # new-token embedding comes from the draft
    hidden1: int = 256
    hidden2: int = 64
    classes: int = HRAD_CLASSES

    @property
    def d_in(self) -> int:
        return self.k_layers * self.d_model + self.d_emb

    def to_dict(self):
        d = asdict(self)
        d["d_in"] = self.d_in
        return d


HRAD = HradConfig()


def key(seed: int) -> jax.Array:
    return jax.random.PRNGKey(seed)


def split_keys(seed: int, n: int):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def tree_cast(params, dtype):
    return jax.tree_util.tree_map(lambda p: p.astype(dtype), params)


def assert_finite(tree, what: str = "tree"):
    for leaf in jax.tree_util.tree_leaves(tree):
        if not bool(jnp.all(jnp.isfinite(leaf))):
            raise FloatingPointError(f"non-finite values in {what}")
