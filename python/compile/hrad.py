"""H-RAD: hybrid rollback-aware draft-structure predictor (paper §5.1).

A 3-class MLP over [last-K target hidden states ⊕ next-token draft
embedding] (Eq. 4-5):
    s_t = 0  all-reject   (hard signal)
    s_t = 1  use draft-model confidence (soft signal)
    s_t = 2  all-accept   (hard signal)

Training (paper App. E.4, adapted): we harvest (z_t, s_t) pairs by running
actual speculative-decoding rounds with the trained tiny pair, label each
round by its verification outcome, then train offline with class
re-weighting + label smoothing (stand-in for the paper's SMOTE -- same
purpose: the all-accept/all-reject classes dominate the truncated-geometric
outcome distribution). Converges in well under a minute on CPU.

The trained MLP is AOT-exported (aot.py) and invoked from Rust once per
draft round -- its cost must stay negligible (paper: 0.38% of step time).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import common, model
from .kernels import ref


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: common.HradConfig, seed: int = 3):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    nrm = lambda k, s, sc: (jax.random.normal(k, s) * sc).astype(jnp.float32)
    return {
        "w1": nrm(k1, (cfg.d_in, cfg.hidden1), cfg.d_in ** -0.5),
        "b1": jnp.zeros((cfg.hidden1,), jnp.float32),
        "w2": nrm(k2, (cfg.hidden1, cfg.hidden2), cfg.hidden1 ** -0.5),
        "b2": jnp.zeros((cfg.hidden2,), jnp.float32),
        "w3": nrm(k3, (cfg.hidden2, cfg.classes), cfg.hidden2 ** -0.5),
        "b3": jnp.zeros((cfg.classes,), jnp.float32),
    }


def mlp_logits(mlp, z):
    h = jax.nn.relu(z @ mlp["w1"] + mlp["b1"])
    h = jax.nn.relu(h @ mlp["w2"] + mlp["b2"])
    return h @ mlp["w3"] + mlp["b3"]


def make_apply_fn(mlp, draft_emb):
    """Closure for AOT export: (features (K*d,), token i32) -> probs (3,).

    The next-token embedding lookup (paper's e_t) happens inside so the Rust
    side only ships raw features + the token id.
    """
    def fn(features, token):
        e = draft_emb[token]
        z = jnp.concatenate([features, e])
        return jax.nn.softmax(mlp_logits(mlp, z[None, :])[0])

    d_feat = draft_emb.shape[1]
    k_d = None  # for doc only
    return fn


# ---------------------------------------------------------------------------
# Trace harvesting: run real SD rounds with the tiny pair
# ---------------------------------------------------------------------------

def harvest_traces(draft_params, target_params, prompts, *, gamma: int = 6,
                   max_new: int = 64, seed: int = 11, temperature: float = 1.0,
                   log=print):
    """Run chain speculative decoding and label every round.

    Returns (features (N, K*d_target), token_ids (N,), labels (N,)) where the
    features are the target's last-K hidden states at the last verified
    position *before* the round (exactly what Rust will feed at runtime).
    """
    d_cfg, t_cfg = common.DRAFT, common.TARGET
    g = gamma
    draft_step = jax.jit(functools.partial(
        model.step, draft_params, d_cfg, use_pallas=False))
    target_step = jax.jit(functools.partial(
        model.step, target_params, t_cfg, use_pallas=False))

    rng = np.random.default_rng(seed)
    feats, toks, labels = [], [], []

    for pi, prompt in enumerate(prompts):
        prompt = list(map(int, prompt))
        d_kv, t_kv = model.empty_kv(d_cfg), model.empty_kv(t_cfg)
        # Prefill both models on the prompt (single block each; prompts are
        # short enough to fit one call when padded to len(prompt)).
        p = jnp.asarray(prompt, jnp.int32)
        _, _, d_kv = draft_step(p, d_kv, jnp.int32(0))
        t_logits, t_hid, t_kv = target_step(p, t_kv, jnp.int32(0))
        cur = len(prompt)
        ctx = list(prompt)
        last_feat = np.asarray(t_hid[-1])          # features at last position
        produced = 0
        while produced < max_new and cur + g + 1 < t_cfg.seq_max:
            # --- draft proposes g tokens ---
            qs, proposal = [], []
            dcur = cur
            for i in range(g):
                tok = jnp.asarray([ctx[-1] if i == 0 else proposal[-1]], jnp.int32)
                lg, _, d_kv = draft_step(tok, d_kv, jnp.int32(dcur))
                if temperature <= 0.0:
                    # Greedy drafting (the serving default on the tiny pair).
                    q = np.zeros(lg.shape[-1]); q[int(jnp.argmax(lg[0]))] = 1.0
                    nxt = int(jnp.argmax(lg[0]))
                else:
                    q = np.asarray(jax.nn.softmax(lg[0] / temperature))
                    nxt = int(rng.choice(len(q), p=q / q.sum()))
                qs.append(q)
                proposal.append(nxt)
                dcur += 1
            # --- target verifies the block [last_ctx_token + proposal[:-1]]
            block = jnp.asarray([ctx[-1]] + proposal[:-1], jnp.int32)
            t_logits, t_hid, t_kv = target_step(block, t_kv, jnp.int32(cur - 1))
            ps = np.asarray(jax.nn.softmax(t_logits, axis=-1))  # (g, V)
            # --- Match (greedy target would always accept argmax; use the
            # stochastic rule to get realistic accept/reject statistics) ---
            n_acc = 0
            for i in range(g):
                if temperature <= 0.0:
                    ok = proposal[i] == int(np.argmax(ps[i]))
                else:
                    pi_, qi_ = ps[i, proposal[i]], qs[i][proposal[i]]
                    ok = rng.random() < min(1.0, pi_ / max(qi_, 1e-9))
                if ok:
                    n_acc += 1
                else:
                    break
            label = 2 if n_acc == g else (0 if n_acc == 0 else 1)
            feats.append(last_feat.copy())
            toks.append(proposal[0])
            labels.append(label)
            # --- advance context by accepted tokens + one corrected token ---
            if n_acc == g:
                accepted = proposal
            else:
                resid = np.maximum(ps[n_acc] - qs[n_acc], 0.0)
                if resid.sum() <= 0:
                    resid = ps[n_acc]
                corrected = int(rng.choice(len(resid), p=resid / resid.sum()))
                accepted = proposal[:n_acc] + [corrected]
            ctx.extend(accepted)
            produced += len(accepted)
            cur += len(accepted)
            # Refresh features at the new last verified position: the verify
            # call covered block positions cur-1..cur+g-2 (before advance);
            # the row for the last *accepted* token is n_acc (0-indexed into
            # the block, clipped).
            row = min(len(accepted) - 1, g - 1)
            last_feat = np.asarray(t_hid[row])
            # Draft cache may now contain garbage past cur; that is fine by
            # the masking contract, but its logical length must be rewound.
            # (The jnp cache itself is static storage; only `dcur` mattered.)
        if log and pi % 8 == 0:
            log(f"[hrad-harvest] prompt {pi}/{len(prompts)} samples={len(labels)}")

    return (np.stack(feats).astype(np.float32), np.asarray(toks, np.int32),
            np.asarray(labels, np.int32))


# ---------------------------------------------------------------------------
# Offline training
# ---------------------------------------------------------------------------

def train_mlp(cfg: common.HradConfig, draft_emb, feats, toks, labels, *,
              epochs: int = 20, batch: int = 32, lr: float = 1e-3,
              smoothing: float = 0.1, seed: int = 5, log=print):
    """Train the 3-class MLP; returns (mlp_params, final_accuracy)."""
    mlp = init_mlp(cfg, seed)
    emb = np.asarray(draft_emb)
    z = np.concatenate([feats, emb[toks]], axis=1).astype(np.float32)
    y = labels

    # Class re-weighting (SMOTE stand-in): inverse-frequency weights.
    counts = np.bincount(y, minlength=cfg.classes).astype(np.float64)
    weights = (counts.sum() / np.maximum(counts, 1.0))
    weights = weights / weights.mean()
    w = jnp.asarray(weights, jnp.float32)

    opt_m = jax.tree_util.tree_map(jnp.zeros_like, mlp)
    opt_v = jax.tree_util.tree_map(jnp.zeros_like, mlp)

    @jax.jit
    def step_fn(mlp, opt_m, opt_v, t, zb, yb):
        def loss_fn(mlp):
            logits = mlp_logits(mlp, zb)
            logp = jax.nn.log_softmax(logits)
            onehot = jax.nn.one_hot(yb, cfg.classes)
            soft = onehot * (1 - smoothing) + smoothing / cfg.classes
            per = -jnp.sum(soft * logp, axis=-1) * w[yb]
            return jnp.mean(per)

        loss, grads = jax.value_and_grad(loss_fn)(mlp)
        b1, b2, eps = 0.9, 0.999, 1e-8
        opt_m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt_m, grads)
        opt_v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_v, grads)
        ms = 1.0 / (1 - b1 ** t)
        vs = 1.0 / (1 - b2 ** t)
        mlp = jax.tree_util.tree_map(
            lambda p, m, v: p - lr * (m * ms) / (jnp.sqrt(v * vs) + eps),
            mlp, opt_m, opt_v)
        return mlp, opt_m, opt_v, loss

    rng = np.random.default_rng(seed)
    n = len(y)
    t = 0
    for ep in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            t += 1
            mlp, opt_m, opt_v, loss = step_fn(
                mlp, opt_m, opt_v, jnp.float32(t),
                jnp.asarray(z[idx]), jnp.asarray(y[idx]))
        if log and (ep % 5 == 0 or ep == epochs - 1):
            acc = evaluate(mlp, z, y)
            log(f"[hrad-train] epoch {ep:2d} loss {float(loss):.4f} acc {acc:.3f}")
    return mlp, evaluate(mlp, z, y)


def evaluate(mlp, z, y):
    pred = np.asarray(jnp.argmax(mlp_logits(mlp, jnp.asarray(z)), axis=-1))
    return float((pred == y).mean())


def confusion(mlp, z, y, classes: int = 3):
    pred = np.asarray(jnp.argmax(mlp_logits(mlp, jnp.asarray(z)), axis=-1))
    cm = np.zeros((classes, classes), dtype=np.int64)
    for t, p in zip(y, pred):
        cm[t, p] += 1
    return cm
