"""L2: decoder-only transformer (draft + target) in JAX.

Two code paths over the same parameters:
  * ``use_pallas=True``  -- attention/FFN via the L1 Pallas kernels; this is
    what aot.py lowers to HLO for the Rust runtime (request path).
  * ``use_pallas=False`` -- pure-jnp reference ops (kernels/ref.py); used for
    training (interpret-mode Pallas is too slow to train through) and as the
    oracle in pytest. Kernel == ref equality is asserted by python/tests.

Shape contract with rust/src/runtime (artifacts/manifest.json):
  decode/verify step(tokens (G,) i32, kv (L,2,H,S,D) f32, cur_len i32[1])
    -> logits (G, V) f32, hiddens (G, K*d_model) f32, new_kv
All shapes static; ``cur_len`` masks the live prefix of the KV cache, and
cache slots >= cur_len are garbage by contract (masked by the bias, then
overwritten by later writes).
"""

import functools

import jax
import jax.numpy as jnp

from . import common
from .kernels import attention as attn_k
from .kernels import ffn as ffn_k
from .kernels import ref


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: common.ModelConfig, seed: int):
    """Init a parameter pytree (dict) with scaled-normal weights."""
    keys = iter(jax.random.split(jax.random.PRNGKey(seed), 4 + 8 * cfg.n_layers))
    d, dh, h, dff, v = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.d_ff, cfg.vocab

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(jnp.float32)

    params = {
        "emb": nrm(next(keys), (v, d), 0.02),
        "pos": nrm(next(keys), (cfg.seq_max, d), 0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "unemb": nrm(next(keys), (d, v), 0.02),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": nrm(next(keys), (d, h * dh), d ** -0.5),
            "wk": nrm(next(keys), (d, h * dh), d ** -0.5),
            "wv": nrm(next(keys), (d, h * dh), d ** -0.5),
            "wo": nrm(next(keys), (h * dh, d), (h * dh) ** -0.5),
            "ln2": jnp.ones((d,), jnp.float32),
            "w1": nrm(next(keys), (d, dff), d ** -0.5),
            "b1": jnp.zeros((dff,), jnp.float32),
            "w2": nrm(next(keys), (dff, d), dff ** -0.5),
            "b2": jnp.zeros((d,), jnp.float32),
        })
    return params


def empty_kv(cfg: common.ModelConfig):
    return jnp.zeros(cfg.kv_shape, jnp.float32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_block(layer, x, kv_layer, cur_len, cfg, use_pallas):
    """Attention over a (T, d) block appended at position cur_len.

    kv_layer: (2, H, S, D) cache for this layer. Returns (out (T, d),
    new_kv_layer). New K/V rows are written at cur_len..cur_len+T-1.
    """
    t = x.shape[0]
    h, dh, s = cfg.n_heads, cfg.d_head, cfg.seq_max
    xn = ref.rmsnorm_ref(x, layer["ln1"])
    q = (xn @ layer["wq"]).reshape(t, h, dh).transpose(1, 0, 2)   # (H,T,D)
    k_new = (xn @ layer["wk"]).reshape(t, h, dh).transpose(1, 0, 2)
    v_new = (xn @ layer["wv"]).reshape(t, h, dh).transpose(1, 0, 2)

    # Scatter new K/V into the static cache at cur_len.
    k_cache = _update_cache(kv_layer[0], k_new, cur_len)
    v_cache = _update_cache(kv_layer[1], v_new, cur_len)

    bias = attn_k.decode_bias(t, s, cur_len)
    if use_pallas:
        o = attn_k.attention(q, k_cache, v_cache, bias)
    else:
        o = ref.attention_ref(q, k_cache, v_cache, bias)
    o = o.transpose(1, 0, 2).reshape(t, h * dh) @ layer["wo"]
    return x + o, jnp.stack([k_cache, v_cache])


def _update_cache(cache, new, cur_len):
    """cache (H, S, D) <- new (H, T, D) written at [:, cur_len:cur_len+T, :]."""
    return jax.lax.dynamic_update_slice(cache, new, (0, cur_len, 0))


def _ffn_block(layer, x, use_pallas):
    xn = ref.rmsnorm_ref(x, layer["ln2"])
    if use_pallas:
        o = ffn_k.ffn(xn, layer["w1"], layer["b1"], layer["w2"], layer["b2"])
    else:
        o = ref.ffn_ref(xn, layer["w1"], layer["b1"], layer["w2"], layer["b2"])
    return x + o


# ---------------------------------------------------------------------------
# Decode / verify step (the AOT-exported function)
# ---------------------------------------------------------------------------

def step(params, cfg: common.ModelConfig, tokens, kv, cur_len, *,
         use_pallas: bool, k_hidden: int = common.HRAD_K):
    """Process a (G,) token block appended at cur_len against the KV cache.

    Returns:
      logits:  (G, V) next-token logits for each position.
      hiddens: (G, K*d) concatenated post-block activations of the last K
               layers (H-RAD explicit features, paper Eq. 4).
      new_kv:  updated cache (L, 2, H, S, D).
    """
    cur_len = jnp.asarray(cur_len, jnp.int32).reshape(())
    t = tokens.shape[0]
    pos = jax.lax.dynamic_slice_in_dim(params["pos"], cur_len, t, axis=0)
    x = params["emb"][tokens] + pos

    new_kv = []
    per_layer = []
    for li, layer in enumerate(params["layers"]):
        x, kv_l = _attn_block(layer, x, kv[li], cur_len, cfg, use_pallas)
        x = _ffn_block(layer, x, use_pallas)
        new_kv.append(kv_l)
        per_layer.append(x)

    k_hidden = min(k_hidden, cfg.n_layers)
    hiddens = jnp.concatenate(per_layer[-k_hidden:], axis=-1)  # (G, K*d)

    xf = ref.rmsnorm_ref(x, params["ln_f"])
    logits = xf @ params["unemb"]
    return logits, hiddens, jnp.stack(new_kv)


def make_step_fn(params, cfg: common.ModelConfig, g: int, *, use_pallas: bool):
    """Close over params (baked as HLO constants) and fix the block size g."""

    def fn(tokens, kv, cur_len):
        return step(params, cfg, tokens, kv, cur_len, use_pallas=use_pallas)

    spec_tok = jax.ShapeDtypeStruct((g,), jnp.int32)
    spec_kv = jax.ShapeDtypeStruct(cfg.kv_shape, jnp.float32)
    spec_len = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (spec_tok, spec_kv, spec_len)


# ---------------------------------------------------------------------------
# Training-time forward (full sequences, no cache)
# ---------------------------------------------------------------------------

def forward_train(params, cfg: common.ModelConfig, tokens):
    """Causal LM forward over (B, T) token batch -> (B, T, V) logits.

    Pure-jnp path (training never touches Pallas; see module docstring).
    """
    b, t = tokens.shape
    x = params["emb"][tokens] + params["pos"][None, :t, :]
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(t)[None, :]
    bias = jnp.where(cols <= rows, 0.0, attn_k.NEG_INF).astype(jnp.float32)

    h, dh = cfg.n_heads, cfg.d_head
    for layer in params["layers"]:
        xn = ref.rmsnorm_ref(x, layer["ln1"])
        q = (xn @ layer["wq"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        k = (xn @ layer["wk"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        v = (xn @ layer["wv"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        o = jax.vmap(ref.attention_ref, in_axes=(0, 0, 0, None))(q, k, v, bias)
        x = x + o.transpose(0, 2, 1, 3).reshape(b, t, h * dh) @ layer["wo"]
        xn = ref.rmsnorm_ref(x, layer["ln2"])
        x = x + ref.ffn_ref(xn, layer["w1"], layer["b1"], layer["w2"], layer["b2"])

    xf = ref.rmsnorm_ref(x, params["ln_f"])
    return xf @ params["unemb"]


def xent_loss(params, cfg, batch):
    """Mean next-token cross-entropy over a (B, T+1) batch."""
    inputs, labels = batch[:, :-1], batch[:, 1:]
    logits = forward_train(params, cfg, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
