"""Build-time training of the tiny draft/target pair (L2).

The paper uses off-the-shelf LLaMA/Vicuna/Deepseek pairs; offline we train
two transformers of different capacity on the same synthetic corpus
(corpus.py) so that the draft only partially matches the target -- the
capacity gap is what produces realistic speculative-decoding acceptance
rates. Runs once under ``make artifacts`` (cached in artifacts/).

Plain Adam, jitted pure-jnp forward (kernels/ref.py); a few hundred steps
per model on CPU.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common, corpus, model


def adam_init(params):
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def train_lm(cfg: common.ModelConfig, tokens: np.ndarray, *, steps: int,
             batch: int = 16, seq: int = 64, seed: int = 0, lr: float = 3e-3,
             log_every: int = 100, log=print):
    """Train one LM on the corpus; returns (params, final_loss)."""
    params = model.init_params(cfg, seed)
    opt = adam_init(params)

    @jax.jit
    def train_step(params, opt, batch_tokens):
        loss, grads = jax.value_and_grad(model.xent_loss)(params, cfg, batch_tokens)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    it = corpus.batches(tokens, batch, seq, seed + 100)
    t0 = time.time()
    loss = None
    for i in range(steps):
        b = jnp.asarray(next(it))
        params, opt, loss = train_step(params, opt, b)
        if log_every and (i % log_every == 0 or i == steps - 1):
            log(f"[train {cfg.name}] step {i:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    return params, float(loss)


def save_params(path: str, params):
    flat, treedef = jax.tree_util.tree_flatten(params)
    np.savez(path, n=len(flat), treedef=str(treedef),
             **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})


def load_params(path: str, like):
    """Load params saved by save_params, using ``like``'s treedef."""
    data = np.load(path)
    flat = [jnp.asarray(data[f"p{i}"]) for i in range(int(data["n"]))]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, flat)
