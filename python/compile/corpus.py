"""Synthetic byte-level corpus for the tiny draft/target pair.

The paper evaluates on HumanEval/GSM8K/CNN-DM prompts; what speculative
decoding actually consumes from a task is the *predictability profile* of
its token stream (DESIGN.md §3). We synthesise a corpus from a sparse
order-2 Markov chain: each 2-byte context admits only a handful of likely
successors with Zipf-ish weights, giving text that is (a) genuinely
learnable by the 4-layer target, (b) only partially learnable by the
2-layer draft -- which is exactly the capacity gap that produces realistic
acceptance rates.

Deterministic: everything derives from an integer seed via numpy's
Philox-free legacy-free Generator.
"""

import numpy as np

from . import common


def build_chain(seed: int, vocab: int = common.VOCAB, branching: int = 3,
                zipf: float = 1.8):
    """Sparse order-2 Markov chain: (vocab, vocab, branching) successors+probs."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, vocab, branching), dtype=np.int32)
    ranks = np.arange(1, branching + 1, dtype=np.float64)
    base = 1.0 / ranks ** zipf
    # Perturb per-context so contexts have different entropies.
    noise = rng.uniform(0.5, 1.5, size=(vocab, vocab, branching))
    probs = base[None, None, :] * noise
    probs /= probs.sum(axis=-1, keepdims=True)
    return succ, probs.astype(np.float64)


def sample_tokens(seed: int, n_tokens: int, vocab: int = common.VOCAB,
                  branching: int = 3, eps: float = 0.01):
    """Sample a token stream from the chain with an eps-uniform smoothing."""
    succ, probs = build_chain(seed, vocab, branching)
    rng = np.random.default_rng(seed + 1)
    out = np.empty(n_tokens, dtype=np.int32)
    a, b = rng.integers(0, vocab), rng.integers(0, vocab)
    for i in range(n_tokens):
        if rng.random() < eps:
            nxt = int(rng.integers(0, vocab))
        else:
            j = rng.choice(branching, p=probs[a, b])
            nxt = int(succ[a, b, j])
        out[i] = nxt
        a, b = b, nxt
    return out


def batches(tokens: np.ndarray, batch: int, seq: int, seed: int):
    """Yield (batch, seq+1) windows forever (inputs = [:, :-1], labels = [:, 1:])."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([tokens[s:s + seq + 1] for s in starts])


def prompts(tokens: np.ndarray, n: int, length: int, seed: int):
    """Deterministic held-out prompt windows for tracing / examples."""
    rng = np.random.default_rng(seed + 7)
    starts = rng.integers(0, len(tokens) - length - 1, size=n)
    return [tokens[s:s + length].copy() for s in starts]
