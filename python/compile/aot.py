"""AOT export: train the tiny pair, train H-RAD, lower everything to HLO text.

This is the single entry point of the build path (``make artifacts``):

  1. synthesise the corpus, train draft + target LMs (train.py, cached);
  2. harvest SD traces and train the H-RAD MLP (hrad.py, cached);
  3. lower four functions to HLO **text** with weights baked as constants:
        draft_step.hlo.txt     (1-token draft decode)
        draft_chunk.hlo.txt    (G-token draft block, used for prefill)
        target_verify.hlo.txt  (G-token target verify, returns H-RAD features)
        hrad_mlp.hlo.txt       (3-class predictor)
  4. write artifacts/manifest.json describing the shape contract.

HLO text -- not ``lowered.compile().serialize()`` -- is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the Rust ``xla`` crate binds) rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Python never runs on the request path: the Rust binary is self-contained
once artifacts/ exists. Re-running is a no-op when inputs are unchanged
(Makefile dependency on python/compile/*.py + cached .npz here).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common, corpus, hrad, model, train

# Training scale (build-time budget: a few minutes on one CPU core).
CORPUS_TOKENS = 240_000
TARGET_STEPS = 1600
DRAFT_STEPS = 1300
HARVEST_PROMPTS = 24
HARVEST_GAMMA = 6
SEED = 0


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the default printer elides baked weights as
    # "{...}", which the Rust-side text parser cannot round-trip.
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, specs, path, log=print):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    log(f"[aot] wrote {path} ({len(text) / 1e6:.2f} MB)")
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def ensure_models(art, log=print):
    """Train (or load cached) draft/target params."""
    tpath = os.path.join(art, "params_target.npz")
    dpath = os.path.join(art, "params_draft.npz")
    t_like = model.init_params(common.TARGET, 0)
    d_like = model.init_params(common.DRAFT, 1)
    if os.path.exists(tpath) and os.path.exists(dpath):
        log("[aot] using cached model params")
        return (train.load_params(dpath, d_like), train.load_params(tpath, t_like))
    tokens = corpus.sample_tokens(SEED, CORPUS_TOKENS)
    target_params, t_loss = train.train_lm(
        common.TARGET, tokens, steps=TARGET_STEPS, seed=0, log=log)
    draft_params, d_loss = train.train_lm(
        common.DRAFT, tokens, steps=DRAFT_STEPS, seed=1, log=log)
    log(f"[aot] trained: target loss {t_loss:.3f}, draft loss {d_loss:.3f}")
    train.save_params(tpath, target_params)
    train.save_params(dpath, draft_params)
    return draft_params, target_params


def ensure_hrad(art, draft_params, target_params, log=print):
    """Harvest traces + train (or load cached) the H-RAD MLP."""
    mpath = os.path.join(art, "params_hrad.npz")
    like = hrad.init_mlp(common.HRAD)
    if os.path.exists(mpath):
        log("[aot] using cached hrad params")
        return train.load_params(mpath, like), None
    tokens = corpus.sample_tokens(SEED, CORPUS_TOKENS)
    prompt_list = corpus.prompts(tokens, HARVEST_PROMPTS, 24, SEED)
    # Greedy harvesting matches the serving configuration on the tiny
    # pair (draft and target both temperature 0, App. E.3 baseline setup).
    feats, toks, labels = hrad.harvest_traces(
        draft_params, target_params, prompt_list, gamma=HARVEST_GAMMA,
        temperature=0.0, log=log)
    counts = np.bincount(labels, minlength=3)
    log(f"[aot] hrad traces: n={len(labels)} class counts={counts.tolist()}")
    mlp, acc = hrad.train_mlp(common.HRAD, draft_params["emb"], feats, toks,
                              labels, log=log)
    log(f"[aot] hrad train accuracy {acc:.3f}")
    train.save_params(mpath, mlp)
    np.savez(os.path.join(art, "hrad_traces.npz"),
             feats=feats, toks=toks, labels=labels)
    return mlp, acc


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="artifacts dir (default: <repo>/artifacts)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(here))
    art = args.out or os.path.join(repo, "artifacts")
    if args.out and args.out.endswith(".hlo.txt"):
        # Legacy Makefile interface passed a file; use its directory.
        art = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(art, exist_ok=True)
    log = (lambda *a, **k: None) if args.quiet else print

    t0 = time.time()
    draft_params, target_params = ensure_models(art, log)
    mlp, _ = ensure_hrad(art, draft_params, target_params, log)

    g = common.GAMMA_MAX + 1
    hashes = {}

    # --- L2 step functions (Pallas kernels inside -> same HLO module) ---
    d_step, d_specs = model.make_step_fn(draft_params, common.DRAFT, 1,
                                         use_pallas=True)
    hashes["draft_step"] = lower_and_write(
        d_step, d_specs, os.path.join(art, "draft_step.hlo.txt"), log)

    d_chunk, dc_specs = model.make_step_fn(draft_params, common.DRAFT, g,
                                           use_pallas=True)
    hashes["draft_chunk"] = lower_and_write(
        d_chunk, dc_specs, os.path.join(art, "draft_chunk.hlo.txt"), log)

    t_verify, tv_specs = model.make_step_fn(target_params, common.TARGET, g,
                                            use_pallas=True)
    hashes["target_verify"] = lower_and_write(
        t_verify, tv_specs, os.path.join(art, "target_verify.hlo.txt"), log)

    # --- H-RAD predictor ---
    apply_fn = hrad.make_apply_fn(mlp, draft_params["emb"])
    h_specs = (jax.ShapeDtypeStruct((common.HRAD.k_layers * common.TARGET.d_model,),
                                    jnp.float32),
               jax.ShapeDtypeStruct((), jnp.int32))
    hashes["hrad_mlp"] = lower_and_write(
        apply_fn, h_specs, os.path.join(art, "hrad_mlp.hlo.txt"), log)

    manifest = {
        "format": "hlo-text/return-tuple",
        "vocab": common.VOCAB,
        "seq_max": common.SEQ_MAX,
        "gamma_max": common.GAMMA_MAX,
        "block": g,
        "hrad": common.HRAD.to_dict(),
        "target": common.TARGET.to_dict(),
        "draft": common.DRAFT.to_dict(),
        "entry_points": {
            "draft_step": {
                "file": "draft_step.hlo.txt",
                "inputs": [["tokens", "i32", [1]],
                           ["kv", "f32", list(common.DRAFT.kv_shape)],
                           ["cur_len", "i32", []]],
                "outputs": [["logits", "f32", [1, common.VOCAB]],
                            ["hiddens", "f32", [1, 2 * common.DRAFT.d_model]],
                            ["kv", "f32", list(common.DRAFT.kv_shape)]],
            },
            "draft_chunk": {
                "file": "draft_chunk.hlo.txt",
                "inputs": [["tokens", "i32", [g]],
                           ["kv", "f32", list(common.DRAFT.kv_shape)],
                           ["cur_len", "i32", []]],
                "outputs": [["logits", "f32", [g, common.VOCAB]],
                            ["hiddens", "f32", [g, 2 * common.DRAFT.d_model]],
                            ["kv", "f32", list(common.DRAFT.kv_shape)]],
            },
            "target_verify": {
                "file": "target_verify.hlo.txt",
                "inputs": [["tokens", "i32", [g]],
                           ["kv", "f32", list(common.TARGET.kv_shape)],
                           ["cur_len", "i32", []]],
                "outputs": [["logits", "f32", [g, common.VOCAB]],
                            ["hiddens", "f32",
                             [g, common.HRAD.k_layers * common.TARGET.d_model]],
                            ["kv", "f32", list(common.TARGET.kv_shape)]],
            },
            "hrad_mlp": {
                "file": "hrad_mlp.hlo.txt",
                "inputs": [["features", "f32",
                            [common.HRAD.k_layers * common.TARGET.d_model]],
                           ["token", "i32", []]],
                "outputs": [["probs", "f32", [common.HRAD_CLASSES]]],
            },
        },
        "hashes": hashes,
    }
    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    log(f"[aot] done in {time.time() - t0:.1f}s -> {art}")


if __name__ == "__main__":
    main()
