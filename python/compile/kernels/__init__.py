"""L1 Pallas kernels (build-time only) + pure-jnp oracles."""
from . import attention, ffn, ref  # noqa: F401
