"""L1: fused decode/verify attention as a Pallas kernel (flash-style).

This is the paper's compute hot-spot: every SpecBranch step is either a
draft decode (Tq = 1 against the draft KV cache) or a target verify
(Tq = GAMMA_MAX + 1 draft tokens against the target KV cache). Both are the
same computation -- masked attention of a short query block against a long
static KV cache -- so one kernel serves both models.

Hardware adaptation (DESIGN.md §2): the paper runs on A100s where this would
be a CUDA flash-attention with threadblock tiling over KV. On TPU the same
insight maps to:
  * grid = (heads, kv_blocks); each step streams one (BLOCK_K, D) KV tile
    HBM -> VMEM via BlockSpec (the role shared memory plays on GPU),
  * online-softmax running max/denominator kept in VMEM across the kv_block
    grid dimension (output revisiting), so the full (Tq, S) score matrix is
    never materialised,
  * tiles padded to MXU-friendly multiples (BLOCK_K a multiple of 128 lanes
    when S allows; D is the head dim and rides the sublane axis).

Must be lowered with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md). Numerics are pinned to
ref.attention_ref by python/tests/test_attention_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, n_kv_blocks: int):
    """One (head, kv_block) grid step of online-softmax attention.

    Block shapes:
      q_ref:    (Tq, D)        -- whole query block for this head
      k_ref:    (BLOCK_K, D)   -- one KV tile
      v_ref:    (BLOCK_K, D)
      bias_ref: (Tq, BLOCK_K)  -- additive mask tile (causal + cache length)
      o_ref:    (Tq, D)        -- final output (written on the last kv step)
      m_ref:    (Tq, 1)        -- running max      (revisited across kv steps)
      l_ref:    (Tq, 1)        -- running sum      (revisited)
      acc_ref:  (Tq, D)        -- running numerator (revisited)
    """
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))

    # (Tq, BLOCK_K) scores for this tile.
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[...].astype(jnp.float32)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)

    p = jnp.exp(s - m_new)                        # (Tq, BLOCK_K)
    correction = jnp.exp(m_prev - m_new)          # (Tq, 1)
    l_new = l_prev * correction + jnp.sum(p, axis=-1, keepdims=True)

    acc = acc_ref[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(kb == n_kv_blocks - 1)
    def _finalize():
        # Fully-masked rows (l == 0) can only happen for padded queries; emit
        # zeros there rather than NaN so downstream slicing stays clean.
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def attention(q, k, v, bias, *, block_k: int = DEFAULT_BLOCK_K):
    """Fused masked attention: softmax(q·kᵀ/√D + bias)·v, one batch element.

    Args / returns exactly match ref.attention_ref: q (H, Tq, D),
    k/v (H, S, D), bias (Tq, S) additive; returns (H, Tq, D) f32.
    """
    h, tq, d = q.shape
    _, s, _ = k.shape
    if s % block_k != 0:
        # Static shapes only: pad KV + bias up to a whole number of tiles.
        pad = block_k - s % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)
        s += pad
    n_kv_blocks = s // block_k

    grid = (h, n_kv_blocks)
    out_shapes = [
        jax.ShapeDtypeStruct((h, tq, d), jnp.float32),  # o
        jax.ShapeDtypeStruct((h, tq, 1), jnp.float32),  # m (scratch-as-output)
        jax.ShapeDtypeStruct((h, tq, 1), jnp.float32),  # l
        jax.ShapeDtypeStruct((h, tq, d), jnp.float32),  # acc
    ]
    o, _, _, _ = pl.pallas_call(
        functools.partial(_attn_kernel, n_kv_blocks=n_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, tq, d), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda hh, kb: (hh, kb, 0)),
            pl.BlockSpec((None, block_k, d), lambda hh, kb: (hh, kb, 0)),
            pl.BlockSpec((tq, block_k), lambda hh, kb: (0, kb)),
        ],
        out_specs=[
            pl.BlockSpec((None, tq, d), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((None, tq, 1), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((None, tq, 1), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((None, tq, d), lambda hh, kb: (hh, 0, 0)),
        ],
        out_shape=out_shapes,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v, bias)
    return o


def decode_bias(tq: int, s: int, cur_len, dtype=jnp.float32):
    """Additive mask for a Tq-token query block appended at position cur_len.

    Query row i sits at absolute position cur_len + i and may attend to all
    cache slots <= that position. Slots >= cur_len + tq are always padding.
    """
    rows = jnp.arange(tq)[:, None]
    cols = jnp.arange(s)[None, :]
    visible = cols <= (cur_len + rows)
    return jnp.where(visible, 0.0, NEG_INF).astype(dtype)
