"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact counterpart here; pytest
asserts allclose between the two across shape/dtype sweeps (hypothesis).
These references are also what the L2 training loop uses (interpret-mode
Pallas is too slow to train with), so kernel == ref is what guarantees the
AOT-exported graph computes the same function the models were trained as.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, bias):
    """Masked multi-head attention, one batch element.

    Args:
      q:    (H, Tq, D) queries.
      k:    (H, S, D) keys (full static cache; padding masked via ``bias``).
      v:    (H, S, D) values.
      bias: (Tq, S) additive mask, 0 for visible and a large negative value
            for masked positions. Encodes both causality and cache length.

    Returns:
      (H, Tq, D) attention output in f32.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum("htd,hsd->hts", q, k) * scale + bias[None, :, :]
    weights = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return jnp.einsum("hts,hsd->htd", weights, v)


def rmsnorm_ref(x, gamma, eps=1e-6):
    """RMSNorm over the last axis: x * gamma / rms(x)."""
    x = x.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma


def gelu_ref(h):
    """tanh-approximated GELU (matches the fused FFN kernel)."""
    return 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h ** 3)))


def ffn_ref(x, w1, b1, w2, b2):
    """2-layer MLP with tanh-GELU, matching kernels/ffn.py."""
    x = x.astype(jnp.float32)
    return gelu_ref(x @ w1 + b1) @ w2 + b2


def softmax_ref(logits, axis=-1):
    m = jnp.max(logits, axis=axis, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)
