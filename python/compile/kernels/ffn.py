"""L1: fused transformer FFN (x·W1 + b1 → GELU → ·W2 + b2) as a Pallas kernel.

The second hot matmul of every decode/verify step. On GPU this is two GEMMs
with an elementwise epilogue fused by cuBLASLt; on TPU we express it as a
single Pallas kernel so the (row-tile, d_ff) intermediate lives entirely in
VMEM and never round-trips to HBM. Grid is over row tiles of the token
block; weights are small enough (d_model·d_ff ≤ 128·256 f32 = 128 KiB) to
sit in VMEM for every grid step, which is the TPU analogue of keeping them
resident in L2 on the GPU.

interpret=True (CPU PJRT); numerics pinned to ref.ffn_ref by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_T = 8


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jax.lax.dot_general(x, w1_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = h + b1_ref[...][None, :]
    h = ref.gelu_ref(h)
    o = jax.lax.dot_general(h, w2_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[...] = (o + b2_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def ffn(x, w1, b1, w2, b2, *, block_t: int = DEFAULT_BLOCK_T):
    """Fused FFN over a (T, d_model) token block; returns (T, d_model) f32."""
    t, d_model = x.shape
    d_ff = w1.shape[1]
    if t % block_t != 0:
        pad = block_t - t % block_t
        x = jnp.pad(x, ((0, pad), (0, 0)))
    tp = x.shape[0]
    grid = (tp // block_t,)
    o = pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
            pl.BlockSpec((d_model,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, d_model), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d_model), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w1, b1, w2, b2)
    return o[:t]
